"""Recovery-behaviour gate over the failure-scenario replay artifact
(the ``compare_predict.py`` of the partition-tolerance subsystem).

Runs against a fresh ``replay.csv`` produced by the extended scenario
sweep (``--scenario no-fault,straggler,crash,partition,crash+revive,
straggler+hedge --write-quorum 1,2 --replication 2``) and asserts the
recovery machinery actually engaged — a sweep that silently stops
partitioning, readmitting, hedging, or charging quorums still produces a
well-formed CSV, and only these semantic gates catch it:

  * the recovery columns must be present in the header (same presence
    check ``compare_predict`` applies to the committed baseline file);
  * every ``partition`` row must show ``failovers > 0`` (cross-partition
    reads failed over to reachable replicas) and ``readmissions >= 1``
    (the heal at the scheduled instant readmitted the cut services);
  * every ``crash+revive`` row must show ``readmissions >= 1`` (the
    revived service rejoined routing; failovers are not required — a
    non-prefetching predictor can have nothing in flight at the crash);
  * hedging must not worsen the worst tail: per (app, workload, quorum)
    the max ``stall_p99_s`` over the ``straggler+hedge`` rows must not
    exceed the max over the matching ``straggler`` rows, and across the
    file at least one hedge must actually have fired
    (``hedged_reads > 0``);
  * every no-fault ``write_quorum > 1`` row on a mutating workload
    (``writes > 0``) must charge the quorum (``quorum_writes > 0``) and
    stall strictly more than its matching W=1 row — synchronous replica
    acks are a consistency cost the virtual clock must price, never hide;
  * with ``--clean-baseline``, the sweep's clean-regime rows (no-fault,
    round-robin, replication 1, write-quorum 1) must be byte-identical on
    shared virtual-clock columns to the committed ``baseline.csv`` rows
    with the same key (wall-clock timing columns are exempt) — fault
    plumbing must be inert when no fault is scheduled.

Usage: PYTHONPATH=src python -m benchmarks.compare_recovery \
    artifacts/predict/scenarios-round-robin/replay.csv \
    [--clean-baseline artifacts/predict/baseline.csv]
"""

from __future__ import annotations

import csv
import sys

from benchmarks.compare_predict import RECOVERY_COLUMNS, _clean_regime

# everything that identifies a cell except the fault regime itself
BaseKey = tuple[str, ...]

#: columns measured on (or scaled by) the wall clock — legitimately
#: different on every run, so the clean-regime identity check skips them;
#: every other column comes off the virtual clock and must match exactly
WALL_COLUMNS = frozenset(
    {"train_seconds", "obs_seconds", "calib_scale", "calibrated_stall_s"})


def _load(path: str) -> tuple[list[dict], list[str]]:
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        rows = list(reader)
        fields = list(reader.fieldnames or [])
    return rows, fields


def _base_key(r: dict) -> BaseKey:
    return (r["app"], r["workload"], r["predictor"], r["cache_capacity"],
            r.get("policy") or "lru", r.get("dispatch") or "per-oid",
            r.get("placement") or "round-robin", r.get("replication") or "1")


def _label(r: dict) -> str:
    return "/".join(_base_key(r)) + (
        f"@{r.get('scenario') or 'no-fault'}/W={r.get('write_quorum') or '1'}"
    )


def _int(r: dict, col: str) -> int:
    v = r.get(col)
    return int(v) if v not in (None, "", "-") else 0


def _float(r: dict, col: str):
    v = r.get(col)
    return float(v) if v not in (None, "", "-") else None


def check(rows: list[dict], fields: list[str]) -> list[str]:
    failures: list[str] = []
    missing = [c for c in RECOVERY_COLUMNS if c not in fields]
    if missing:
        failures.append(
            f"recovery columns missing from header: {', '.join(missing)}")
        return failures

    # index: (base key, scenario, write_quorum) -> row; scenarios compare
    # against their peers inside the same base cell
    by_cell: dict[tuple[BaseKey, str, str], dict] = {}
    for r in rows:
        by_cell[(_base_key(r), r.get("scenario") or "no-fault",
                 r.get("write_quorum") or "1")] = r

    total_hedged = 0
    saw_partition = saw_revive = saw_hedge = saw_quorum = False
    for r in rows:
        scenario = r.get("scenario") or "no-fault"
        wq = r.get("write_quorum") or "1"
        if scenario == "partition":
            saw_partition = True
            if _int(r, "failovers") <= 0:
                failures.append(f"{_label(r)}: partition ran with zero "
                                "failovers (cross-partition reads never "
                                "failed over)")
            if _int(r, "readmissions") < 1:
                failures.append(f"{_label(r)}: partition healed without a "
                                "readmission")
        elif scenario == "crash+revive":
            # failovers may legitimately be zero here: a non-prefetching
            # predictor can have nothing in flight at the crash instant and
            # routing just avoids the dead replica — the readmission is the
            # invariant
            saw_revive = True
            if _int(r, "readmissions") < 1:
                failures.append(f"{_label(r)}: revived service was never "
                                "readmitted")
        elif scenario == "straggler+hedge":
            saw_hedge = True
            total_hedged += _int(r, "hedged_reads")
            if by_cell.get((_base_key(r), "straggler", wq)) is None:
                failures.append(f"{_label(r)}: no matching straggler row to "
                                "compare the hedged tail against")
        if scenario == "no-fault" and wq != "1" and _int(r, "writes") > 0:
            saw_quorum = True
            if _int(r, "quorum_writes") <= 0:
                failures.append(f"{_label(r)}: W={wq} write workload charged "
                                "no quorum writes")
            base = by_cell.get((_base_key(r), scenario, "1"))
            if base is None:
                failures.append(f"{_label(r)}: no matching W=1 row to price "
                                "the quorum against")
            else:
                cost = _float(r, "stall_seconds")
                free = _float(base, "stall_seconds")
                if cost is not None and free is not None and cost <= free:
                    failures.append(
                        f"{_label(r)}: W={wq} stall {cost:.4f}s <= W=1 "
                        f"{free:.4f}s — quorum acks came for free")
    if saw_hedge and total_hedged == 0:
        failures.append("straggler+hedge rows present but no hedge ever "
                        "fired (hedged_reads == 0 across the file)")
    # the hedge gate is on the WORST tail per (app, workload, quorum): the
    # race bounds the slowest demand read near hedge_delay + one healthy
    # service time, so the max p99 across predictors must not grow; single
    # cells with an already-tiny tail can wiggle either way because the
    # winning replica reshapes downstream routing, so they are not gated
    # individually
    worst: dict[tuple, dict[str, float]] = {}
    for r in rows:
        scenario = r.get("scenario") or "no-fault"
        if scenario not in ("straggler", "straggler+hedge"):
            continue
        p99 = _float(r, "stall_p99_s")
        if p99 is None:
            continue
        group = worst.setdefault(
            (r["app"], r["workload"], r.get("write_quorum") or "1"), {})
        group[scenario] = max(group.get(scenario, 0.0), p99)
    for (app, workload, wq), group in sorted(worst.items()):
        if "straggler" in group and "straggler+hedge" in group:
            if group["straggler+hedge"] > group["straggler"]:
                failures.append(
                    f"{app}/{workload}/W={wq}: worst hedged stall_p99_s "
                    f"{group['straggler+hedge']:.6f} > worst unhedged "
                    f"{group['straggler']:.6f} — hedging made the slowest "
                    "predictor's tail worse")
    for name, seen in (("partition", saw_partition),
                       ("crash+revive", saw_revive),
                       ("straggler+hedge", saw_hedge)):
        if not seen:
            failures.append(f"no {name} rows in the sweep — scenario matrix "
                            "lost a leg")
    if not saw_quorum:
        failures.append("no W>1 mutating no-fault rows in the sweep — the "
                        "quorum pricing leg is gone")
    return failures


def check_clean_baseline(rows: list[dict], baseline_path: str) -> list[str]:
    """Clean-regime rows of the sweep must be byte-identical, column by
    shared column, to the committed baseline rows with the same key: the
    recovery plumbing (fault-event timeline, quorum hooks, hedge race)
    must cost nothing when no fault is scheduled."""
    base_rows, base_fields = _load(baseline_path)
    base_by_key = {
        (r["app"], r["workload"], r["predictor"], r["cache_capacity"],
         r.get("policy") or "lru", r.get("dispatch") or "per-oid"): r
        for r in base_rows if _clean_regime(r)
    }
    failures: list[str] = []
    compared = 0
    for r in rows:
        if not _clean_regime(r):
            continue
        key = (r["app"], r["workload"], r["predictor"], r["cache_capacity"],
               r.get("policy") or "lru", r.get("dispatch") or "per-oid")
        base = base_by_key.get(key)
        if base is None:
            continue  # sweep params outside the baseline sweep; nothing to pin
        compared += 1
        for col in base_fields:
            if col not in r or col in WALL_COLUMNS:
                continue
            if (r.get(col) or "") != (base.get(col) or ""):
                failures.append(
                    f"{'/'.join(key)}: clean-regime {col} drifted from "
                    f"baseline: {r.get(col)!r} != {base.get(col)!r}")
    if compared == 0:
        failures.append(
            f"no clean-regime rows overlapped {baseline_path} — the "
            "identity check compared nothing")
    return failures


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly generated scenario-sweep replay.csv")
    ap.add_argument("--clean-baseline", default=None, metavar="BASELINE_CSV",
                    help="also require clean-regime rows to match this "
                         "committed baseline byte-for-byte on shared columns")
    ap.add_argument("--clean-only", action="store_true",
                    help="run only the clean-regime identity check (for a "
                         "no-fault R=1/W=1 file that has no fault rows to "
                         "hold the scenario gates to)")
    args = ap.parse_args(argv)
    if args.clean_only and not args.clean_baseline:
        ap.error("--clean-only requires --clean-baseline")
    rows, fields = _load(args.current)
    failures = [] if args.clean_only else check(rows, fields)
    if args.clean_baseline:
        failures += check_clean_baseline(rows, args.clean_baseline)
    if failures:
        print("RECOVERY REGRESSION:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    if args.clean_only:
        print(f"recovery gates: clean-regime rows of {args.current} are "
              f"byte-identical to {args.clean_baseline} on shared columns")
    else:
        n_fault = sum(1 for r in rows
                      if (r.get("scenario") or "no-fault") != "no-fault")
        print(f"recovery gates: {len(rows)} rows ({n_fault} fault-regime) — "
              "partition failover/readmission, hedged tail, and quorum "
              "pricing all engaged")
    return 0


if __name__ == "__main__":
    sys.exit(main())
