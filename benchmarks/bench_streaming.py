"""Weight-streaming benchmark — the TPU-side analogue of the paper's
evaluation: plan-driven (CAPre) vs depth-limited (ROP) vs on-demand
host->device parameter streaming for a layer-by-layer decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.access_plan import build_access_plan
from repro.models.model import Model
from repro.runtime.prefetch import HostParamStore, WeightStreamer


def run(reps: int = 3) -> list[str]:
    cfg = get_smoke_config("yi_34b").replace(n_layers=12, d_model=128, d_ff=384, n_heads=8, n_kv_heads=2, head_dim=0)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    plan = build_access_plan(
        lambda p, c, t: model.decode_step(p, c, t, 8),
        model.abstract_params(),
        model.abstract_cache(4, 64),
        jax.ShapeDtypeStruct((4, 1), jnp.int32),
    )
    lines = []
    base = None
    for mode in (None, "rop", "capre"):
        walls, stalls, hits = [], 0, 0
        for _ in range(reps):
            store = HostParamStore(params, bandwidth_gbps=1.0, base_latency_s=400e-6)
            ws = WeightStreamer(store, plan=plan, mode=mode, k_ahead=3, workers=8)
            walls.append(ws.run_plan(compute_s_per_group=1.5e-3))
            stalls, hits = ws.metrics.stalls, ws.metrics.prefetch_hits
            ws.close()
        mean = sum(walls) / len(walls)
        if mode is None:
            base = mean
        improvement = f"improvement={100 * (1 - mean / base):.1f}%,stalls={stalls},hits={hits}"
        lines.append(f"streaming/{mode or 'none'},{mean * 1e6:.0f},{improvement}")
    return lines
