"""Weight-streaming benchmark — the TPU-side analogue of the paper's
evaluation: plan-driven (CAPre) vs depth-limited (ROP) vs on-demand
host->device parameter streaming for a layer-by-layer decode.

Prefetching modes run the same ``--dispatch per-oid,batch`` A/B the object
store benches sweep (one pool task per path vs strided lanes per plan
group), and every cell records its :class:`StreamMetrics` plus a
``stream_stall_s`` histogram through a shared ``repro.obs.Registry`` —
the p99 per-``get`` wait rides along in the derived column.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.access_plan import build_access_plan
from repro.models.model import Model
from repro.obs import Registry
from repro.runtime.prefetch import HostParamStore, WeightStreamer

DISPATCH_MODES = ("batch", "per-oid")


def run(reps: int = 3) -> list[str]:
    cfg = get_smoke_config("yi_34b").replace(n_layers=12, d_model=128, d_ff=384, n_heads=8, n_kv_heads=2, head_dim=0)
    model = Model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    plan = build_access_plan(
        lambda p, c, t: model.decode_step(p, c, t, 8),
        model.abstract_params(),
        model.abstract_cache(4, 64),
        jax.ShapeDtypeStruct((4, 1), jnp.int32),
    )
    lines = []
    base = None
    for mode in (None, "rop", "capre"):
        # the on-demand reference never prefetches, so it has no dispatch
        # layer to A/B; prefetching modes sweep both arms
        for dispatch in DISPATCH_MODES[:1] if mode is None else DISPATCH_MODES:
            registry = Registry()
            walls = []
            stalls = hits = batches = dedup = 0
            for _ in range(reps):
                store = HostParamStore(params, bandwidth_gbps=1.0, base_latency_s=400e-6)
                ws = WeightStreamer(store, plan=plan, mode=mode, k_ahead=3, workers=8,
                                    dispatch=dispatch, registry=registry)
                walls.append(ws.run_plan(compute_s_per_group=1.5e-3))
                stalls, hits = ws.metrics.stalls, ws.metrics.prefetch_hits
                batches, dedup = ws.metrics.batch_dispatches, ws.metrics.dedup_suppressed
                ws.close()
            mean = sum(walls) / len(walls)
            if base is None:
                base = mean
            p99 = registry.percentiles("stream_stall_s")[1]
            derived = (f"improvement={100 * (1 - mean / base):.1f}%,stalls={stalls},"
                       f"hits={hits},batches={batches},dedup={dedup},"
                       f"p99_stall_us={0.0 if p99 is None else p99 * 1e6:.0f}")
            name = mode or "none"
            if mode is not None and dispatch != "batch":
                name = f"{name}_{dispatch}"
            lines.append(f"streaming/{name},{mean * 1e6:.0f},{derived}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
