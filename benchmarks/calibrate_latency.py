"""Latency-model calibration report (ROADMAP open item): fit the virtual
clock against measured wall clock.

The replay engine (``repro.predict.evaluate``) scores predictors on a pure
arithmetic clock (``pos.latency.REPLAY``), while ``benchmarks/
bench_predictors`` measures the same (app, predictor) cells with real
sleeps (``benchmarks.common.BENCH_LATENCY``).  Both express the value of
prefetching as a *delta against the no-prefetch reference*:

  * simulated: ``baseline_stall_seconds - stall_seconds``  (disk seconds
    removed from the virtual application's critical path);
  * measured:  ``mean_s(none) - mean_s(mode)``              (wall seconds
    removed from the real application thread).

This report joins the two CSVs on (workload, predictor, cache capacity,
policy, dispatch), fits the least-squares scale ``measured ~ scale *
simulated`` per app and overall, and writes
``artifacts/predict/calibration.csv`` with the fitted scales and per-row
residuals.  A small residual spread means the virtual clock *predicts*
wall-clock movement — the property the regression gate's
``timely_coverage`` tolerance implicitly relies on; a drifting scale or a
fat residual names the (app, predictor) cell where the cost model and the
implementation disagree.

Usage: PYTHONPATH=src python -m benchmarks.calibrate_latency \
    [--bench artifacts/predict/bench.csv] [--replay artifacts/predict/replay.csv] \
    [--out artifacts/predict/calibration.csv]
"""

from __future__ import annotations

import csv
import os
import sys
from dataclasses import dataclass
from typing import Optional

#: bench-mode label -> replay predictor name
MODE_TO_PREDICTOR = {
    "rop_d2": "rop",
    "capre": "static-capre",
    "markov": "markov-miner",
    "hybrid": "hybrid",
}

CAL_COLUMNS = (
    "app", "workload", "predictor", "dispatch", "cache_capacity", "policy",
    "measured_delta_s", "simulated_delta_s", "scale_app", "scale_global",
    "predicted_delta_s", "residual_s",
)


@dataclass
class Pair:
    app: str
    workload: str
    predictor: str
    dispatch: str
    cache_capacity: str
    policy: str
    measured: float  # wall seconds saved vs the no-prefetch run
    simulated: float  # virtual stall seconds saved vs the no-prefetch replay


def _read(path: str) -> list[dict]:
    with open(path, newline="") as f:
        return list(csv.DictReader(f))


def _bench_cells(rows: list[dict]) -> dict:
    """(app, workload, capacity, policy, mode, dispatch) -> mean_s, plus the
    no-prefetch reference per (app, workload, capacity, policy)."""
    cells: dict = {}
    for r in rows:
        if not r.get("benchmark", "").startswith("predictors_"):
            continue
        app = r["benchmark"][len("predictors_"):]
        key = (
            app,
            r.get("workload") or r["config"],
            r.get("cache_capacity") or "0",
            r.get("policy") or "lru",
            r["mode"],
            r.get("dispatch") or "",
        )
        cells[key] = float(r["mean_s"])
    return cells


def collect_pairs(bench_rows: list[dict], replay_rows: list[dict]) -> list[Pair]:
    bench = _bench_cells(bench_rows)
    none_ref = {k[:4]: v for k, v in bench.items() if k[4] == "none"}
    pairs: list[Pair] = []
    for r in replay_rows:
        predictor = r["predictor"]
        mode = next((m for m, p in MODE_TO_PREDICTOR.items() if p == predictor), None)
        if mode is None or not r.get("stall_seconds"):
            continue
        app_key = r["app"]
        # the mutating bank traversal benches under its own catalog key
        if r["workload"] == "setAllTransCustomers":
            app_key = "bank_write"
        cell = (app_key, r["workload"], r.get("cache_capacity") or "0",
                r.get("policy") or "lru", mode, r.get("dispatch") or "")
        if cell not in bench or cell[:4] not in none_ref:
            continue
        simulated = float(r["baseline_stall_seconds"]) - float(r["stall_seconds"])
        measured = none_ref[cell[:4]] - bench[cell]
        pairs.append(Pair(app_key, r["workload"], predictor, cell[5],
                          cell[2], cell[3], measured, simulated))
    return pairs


def _fit(pairs: list[Pair]) -> Optional[float]:
    """Least-squares through the origin: measured ~ scale * simulated."""
    num = sum(p.measured * p.simulated for p in pairs)
    den = sum(p.simulated * p.simulated for p in pairs)
    return num / den if den else None


def write_report(pairs: list[Pair], out_path: str) -> str:
    scale_global = _fit(pairs)
    by_app: dict[str, list[Pair]] = {}
    for p in pairs:
        by_app.setdefault(p.app, []).append(p)
    app_scales = {app: _fit(ps) for app, ps in by_app.items()}
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w", newline="") as f:
        writer = csv.writer(f)
        writer.writerow(CAL_COLUMNS)
        for p in sorted(pairs, key=lambda p: (p.app, p.workload, p.predictor,
                                              p.dispatch, p.cache_capacity)):
            scale_app = app_scales.get(p.app)
            predicted = (scale_app or 0.0) * p.simulated
            writer.writerow([
                p.app, p.workload, p.predictor, p.dispatch, p.cache_capacity,
                p.policy, f"{p.measured:.6f}", f"{p.simulated:.6f}",
                "" if scale_app is None else f"{scale_app:.4f}",
                "" if scale_global is None else f"{scale_global:.4f}",
                f"{predicted:.6f}", f"{p.measured - predicted:.6f}",
            ])
    return out_path


def summarize(pairs: list[Pair]) -> str:
    lines = []
    scale_global = _fit(pairs)
    by_app: dict[str, list[Pair]] = {}
    for p in pairs:
        by_app.setdefault(p.app, []).append(p)
    for app, ps in sorted(by_app.items()):
        scale = _fit(ps)
        if scale is None:
            lines.append(f"{app}: no simulated signal (all deltas 0)")
            continue
        resid = [p.measured - scale * p.simulated for p in ps]
        worst = max(zip((abs(r) for r in resid), ps))
        lines.append(
            f"{app}: scale={scale:.3f} over {len(ps)} cells, "
            f"max |residual| {worst[0] * 1e3:.2f}ms "
            f"({worst[1].predictor}/{worst[1].dispatch or '-'})"
        )
    if scale_global is not None:
        lines.append(f"global: scale={scale_global:.3f} over {len(pairs)} cells "
                     "(measured wall delta per simulated stall delta)")
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", default="artifacts/predict/bench.csv",
                    help="bench_predictors CSV (measured wall clock)")
    ap.add_argument("--replay", default="artifacts/predict/replay.csv",
                    help="evaluate.py CSV (virtual clock)")
    ap.add_argument("--out", default="artifacts/predict/calibration.csv")
    args = ap.parse_args(argv)
    for path in (args.bench, args.replay):
        if not os.path.exists(path):
            print(f"calibrate_latency: missing input {path} — run "
                  "benchmarks.bench_predictors / repro.predict.evaluate first")
            return 1
    pairs = collect_pairs(_read(args.bench), _read(args.replay))
    if not pairs:
        print("calibrate_latency: no joinable (app, predictor) cells between "
              f"{args.bench} and {args.replay} (sweep capacities/policies/"
              "dispatch must overlap)")
        return 1
    print(summarize(pairs))
    print(f"# wrote {write_report(pairs, args.out)} ({len(pairs)} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
