"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell on the single-pod mesh:

  compute term    = FLOPs / (chips * 197e12)          [bf16 peak, v5e]
  memory term     = bytes / (chips * 819e9)           [HBM bw]
  collective term = coll_bytes_per_device / 50e9      [ICI link bw]
                    (== global coll bytes / (chips * link_bw))

FLOPs/bytes are the loop-aware jaxpr totals (launch/costmodel.py; XLA's own
cost_analysis counts while bodies once — both are recorded in the artifact).
Collective bytes come from the partitioned HLO with while-trip
multiplication (launch/hlo_parse.py).

The bound on achievable MFU for the cell is
  mfu_bound = (MODEL_FLOPS / (chips * peak)) / max(terms)
— the score the §Perf hillclimbs push up by driving the dominant term down.
"""

from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

ART_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

ADVICE = {
    "compute": "raise arithmetic efficiency: cut dispatch/remat redundancy so HLO flops approach MODEL_FLOPS",
    "memory": "cut HBM traffic: fuse elementwise chains, reuse KV blocks in VMEM (flash kernels), quantize cache",
    "collective": "cut/overlap collectives: reduce-scatter instead of all-gather+all-reduce, async overlap with compute, shrink dtype on the wire",
}


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for f in sorted(ART_DIR.glob(f"*__{mesh}.json")):
        d = json.loads(f.read_text())
        if d.get("status") == "ok":
            cells.append(d)
        elif d.get("status") == "skipped":
            cells.append(d)
    return cells


def roofline_terms(cell: dict) -> dict:
    chips = cell.get("chips", 256)
    t_compute = cell["jaxpr_flops"] / (chips * PEAK_FLOPS)
    t_memory = cell["jaxpr_bytes"] / (chips * HBM_BW)
    t_coll = cell.get("hlo_collective_bytes_per_device", 0.0) / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    ideal = cell["model_flops"] / (chips * PEAK_FLOPS)
    bound = ideal / max(max(terms.values()), 1e-30)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": cell["model_flops"],
        "useful_ratio": cell["model_flops"] / max(cell["jaxpr_flops"], 1e-30),
        "mfu_bound": bound,
        "advice": ADVICE[dominant],
    }


def table(mesh: str = "single") -> list[str]:
    """CSV lines for benchmarks.run + the detailed artifact."""
    rows = []
    detailed = []
    for cell in load_cells(mesh):
        name = f"roofline/{cell['arch']}/{cell['shape']}"
        if cell["status"] == "skipped":
            rows.append(f"{name},0,skipped")
            continue
        r = roofline_terms(cell)
        detailed.append({**cell, **r})
        rows.append(
            f"{name},{r['t_compute_s']*1e6:.1f},"
            f"dominant={r['dominant']};mem_us={r['t_memory_s']*1e6:.1f};"
            f"coll_us={r['t_collective_s']*1e6:.1f};mfu_bound={r['mfu_bound']:.3f};"
            f"useful={r['useful_ratio']:.3f}"
        )
    out = ART_DIR.parent / f"roofline_{mesh}.json"
    out.write_text(json.dumps(detailed, indent=1, default=str))
    return rows


def markdown_table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | MODEL_FLOPS/HLO | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cell in load_cells(mesh):
        if cell["status"] == "skipped":
            lines.append(
                f"| {cell['arch']} | {cell['shape']} | — | — | — | *skipped: full attention at 500k* | — | — |"
            )
            continue
        r = roofline_terms(cell)
        lines.append(
            f"| {cell['arch']} | {cell['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.3f} | {r['mfu_bound']:.3f} |"
        )
    return "\n".join(lines)


def run() -> list[str]:
    return table("single")


if __name__ == "__main__":
    print(markdown_table("single"))
