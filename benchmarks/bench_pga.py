"""Princeton Graph Algorithms benchmark — paper Figure 16 (DFS behaves like
Wordcount; Bellman-Ford's data-dependent order defeats every compile-time
predictor, but CAPre knows there is nothing to prefetch and adds ~no
overhead while ROP keeps issuing useless loads)."""

from __future__ import annotations

from repro.apps.pga import build_pga_app, populate_pga
from repro.pos.interp import ObjRef

from .common import MODES_SHORT, BenchResult, run_modes

MODES_PGA = (
    ("none", None, 0),
    ("rop_d1", "rop", 1),
    ("rop_d2", "rop", 2),
    ("capre", "capre", 0),
)


def run(reps: int = 3, n_vertices: int = 400) -> list[BenchResult]:
    results = []

    state = {}

    def populate(store):
        g, src = populate_pga(store, n_vertices=n_vertices, out_degree=4)
        state[id(store)] = src
        return g

    results += run_modes(
        "pga_dfs",
        f"v{n_vertices}",
        build_pga_app,
        populate,
        lambda s, root: s.execute(root, "dfs"),
        modes=MODES_PGA,
        reps=reps,
    )
    results += run_modes(
        "pga_bellman_ford",
        f"v{n_vertices}",
        build_pga_app,
        populate,
        lambda s, root: s.execute(root, "bellmanFord", ObjRef(state[id(s.store)])),
        modes=MODES_PGA,
        reps=reps,
    )
    return results
