"""Static-code-analysis cost & corpus statistics — paper section 7.1
(Table 4, Figure 8) and section 4.4 (Table 2)."""

from __future__ import annotations

import statistics
import time

from repro.apps.bank import build_bank_app
from repro.apps.kmeans import build_kmeans_app
from repro.apps.oo7 import build_oo7_app
from repro.apps.pga import build_pga_app
from repro.apps.wordcount import build_wordcount_app
from repro.core.corpus import generate_corpus
from repro.core.hints import analyze_application
from repro.pos.client import LogicModule

BENCH_APPS = {
    "oo7": build_oo7_app,
    "wordcount": build_wordcount_app,
    "kmeans": build_kmeans_app,
    "pga": build_pga_app,
    "bank": build_bank_app,
}


def table4() -> list[str]:
    """Per benchmark: 'compilation' (AST->IR lowering) vs CAPre analysis
    time.  The paper's claim: analysis never exceeds compilation by much and
    is paid once, before execution."""
    lm = LogicModule()
    rows = []
    for name, build in BENCH_APPS.items():
        reg = lm.register(build())
        rows.append(
            f"analysis_time/{name},{reg.analysis_time_s * 1e6:.0f},"
            f"lowering_us={reg.lowering_time_s * 1e6:.0f}"
        )
    return rows


def figure8_corpus(n_apps: int = 40) -> list[str]:
    """Analysis-time distribution over the synthetic corpus."""
    times = []
    for app in generate_corpus(n_apps=n_apps):
        t0 = time.perf_counter()
        analyze_application(app)
        times.append(time.perf_counter() - t0)
    return [
        f"analysis_time/corpus_mean,{statistics.mean(times) * 1e6:.0f},n={len(times)}",
        f"analysis_time/corpus_median,{statistics.median(times) * 1e6:.0f},",
        f"analysis_time/corpus_max,{max(times) * 1e6:.0f},",
    ]


def table2_corpus(n_apps: int = 40) -> list[str]:
    """Branch-dependence statistics over the corpus (paper Table 2: on
    average ~67.5% of conditionals, ~82% of loops and ~88.8% of methods
    trigger no branch-dependent navigations)."""
    pct_methods, pct_conds, pct_loops = [], [], []
    apps = generate_corpus(n_apps=n_apps) + [b() for b in BENCH_APPS.values()]
    for app in apps:
        s = analyze_application(app).stats
        pct_methods.append(s.pct_methods_no_bd)
        if s.n_conditionals:
            pct_conds.append(s.pct_conditionals_no_bd)
        if s.n_loops:
            pct_loops.append(s.pct_loops_no_bd)
    return [
        f"branch_dep/methods_no_bd_pct,{statistics.mean(pct_methods):.1f},paper=88.8",
        f"branch_dep/conds_no_bd_pct,{statistics.mean(pct_conds):.1f},paper=67.5",
        f"branch_dep/loops_no_bd_pct,{statistics.mean(pct_loops):.1f},paper=82.0",
    ]


def run() -> list[str]:
    return table4() + figure8_corpus() + table2_corpus()
