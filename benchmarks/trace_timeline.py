"""Export prefetch-lifecycle timelines as Chrome-trace / Perfetto JSON —
the observability acceptance harness (DESIGN.md section 3.7).

Runs the same app on both clocks and exports both timelines:

  * **wall** — a live ``ObjectStore`` run (sleeping latency model, span
    tracing on) of the first requested app, exported to
    ``<out>/<app>_wall.trace.json``; a plan-driven ``WeightStreamer`` run
    rides along in the same file as its own producer track (pid 9000,
    "weight-streamer"), so store lanes and stream fetch lanes share one
    Perfetto timeline;
  * **virtual** — a deterministic ``VirtualReplay`` of every requested
    app's recorded trace under static-capre, exported to
    ``<out>/<app>_replay.trace.json``.

Both exports carry the tracer's instant markers (demand-steal, failover,
service-down) on their service's track.

Every export is validated in-process (span lifecycle invariants, Chrome
trace schema, >= 4 lifecycle phases per loaded prefetch span) — a
violation is a non-zero exit, which is what the CI job gates on.  The
stall histograms of every run land in ``<out>/histograms.csv``.

Open a trace at https://ui.perfetto.dev (or chrome://tracing): one process
track per Data Service, one thread track per disk lane, counter tracks for
disk-slot and demand-queue occupancy.

Usage: PYTHONPATH=src python -m benchmarks.trace_timeline \
    [--apps bank,oo7] [--out artifacts/obs]
"""

from __future__ import annotations

import argparse
import csv
import os
from typing import Optional

from repro.obs import (
    Observability,
    Tracer,
    check_span_invariants,
    chrome_trace,
    full_lifecycle_phase_counts,
    validate_flow_pairing,
    write_chrome_trace,
)
from repro.pos.client import POSClient, SessionConfig
from repro.predict import make_pos_predictor
from repro.predict.calibration import load_calibration
from repro.predict.evaluate import _catalog, record_workload, replay

from .common import BENCH_LATENCY


def _validate(name: str, spans, clock: str) -> list[str]:
    """Lifecycle + export-schema + phase-coverage checks for one run.
    Returns human-readable problems (empty = pass)."""
    problems = [f"{name}: {p}" for p in check_span_invariants(spans)]
    obj = chrome_trace(spans, clock=clock)
    phases = full_lifecycle_phase_counts(obj)
    loaded = [s for s in spans if s.kind == "prefetch" and s.load_done_t is not None]
    for span in loaded:
        if phases.get(span.oid, 0) < 4:
            problems.append(
                f"{name}: oid={span.oid} exported only "
                f"{phases.get(span.oid, 0)} lifecycle phases (< 4)"
            )
    if not loaded:
        problems.append(f"{name}: no loaded prefetch spans at all")
    # flow arrows: every used prefetch (hit/partial) must export a paired
    # prediction -> load -> demand flow chain, and no arrow may dangle
    problems += [f"{name}: {p}" for p in validate_flow_pairing(obj)]
    used = [s for s in spans
            if s.kind == "prefetch" and s.outcome in ("hit", "partial")]
    n_flows = len({ev.get("id") for ev in obj.get("traceEvents", [])
                   if ev.get("ph") == "s"})
    if len(used) != n_flows:
        problems.append(
            f"{name}: {len(used)} used prefetch spans but {n_flows} flow arrows"
        )
    return problems


def _hist_row(run: str, clock: str, metric: str, labels: dict, snap: dict) -> dict:
    return {
        "run": run, "clock": clock, "metric": metric,
        "labels": ";".join(f"{k}={v}" for k, v in sorted(labels.items())),
        "count": snap.get("count", ""), "sum_s": snap.get("sum", ""),
        "min_s": snap.get("min", ""), "max_s": snap.get("max", ""),
        "p50_s": snap.get("p50", ""), "p99_s": snap.get("p99", ""),
        "p999_s": snap.get("p999", ""),
    }


def stream_run() -> tuple[list, list[str]]:
    """A small plan-driven WeightStreamer run with its own tracer; returns
    (spans, problems).  Its spans carry ``service=STREAM_PID`` so they merge
    into the store's timeline as a separate producer track."""
    import numpy as np

    from repro.core.access_plan import AccessRecord, PrefetchPlan
    from repro.runtime.prefetch import HostParamStore, WeightStreamer

    n = 8
    params = {f"layer{i}": {"w": np.zeros((128, 128), np.float32)} for i in range(n)}
    plan = PrefetchPlan(records=[
        AccessRecord(path=f"layer{i}.w", first_use=i, nbytes=128 * 128 * 4,
                     shape=(128, 128))
        for i in range(n)
    ])
    store = HostParamStore(params, bandwidth_gbps=8.0, base_latency_s=200e-6)
    tracer = Tracer(session="stream")
    ws = WeightStreamer(store, plan=plan, mode="capre", k_ahead=2, tracer=tracer)
    ws.run_plan(compute_s_per_group=500e-6)
    ws.close()
    spans = tracer.spans()
    problems = [f"stream/wall: {p}" for p in check_span_invariants(spans)]
    if not any(s.kind == "prefetch" and s.load_done_t is not None for s in spans):
        problems.append("stream/wall: no loaded stream prefetch spans")
    return spans, problems


def wall_run(app: str, out_dir: str, hist_rows: list) -> tuple[str, list[str]]:
    """One live store run with full span tracing; returns (trace path,
    validation problems).  A WeightStreamer run is merged into the same
    trace file as its own producer track."""
    from repro.runtime.prefetch import STREAM_PID

    wl = _catalog()[app]
    client = POSClient(n_services=4, latency=BENCH_LATENCY)
    obs = Observability(tracing=True)
    client.store.attach_obs(obs)
    client.register(wl.build_app())
    root = wl.populate(client.store)
    with client.session(wl.name, mode="capre", parallel_workers=16,
                        session_label=f"{app}-wall") as s:
        wl.run_once(s, root)
        s.drain(30.0)
    # whatever is still resident-but-never-demanded terminates now, so the
    # invariant check below sees a complete lifecycle for every span
    obs.tracer.drop_active("run-end")
    spans = obs.tracer.spans()
    problems = _validate(f"{app}/wall", spans, clock="wall")
    stream_spans, stream_problems = stream_run()
    problems += stream_problems
    path = os.path.join(out_dir, f"{app}_wall.trace.json")
    if not problems:
        write_chrome_trace(path, spans + stream_spans, clock="wall",
                           instants=obs.tracer.instants(),
                           process_names={STREAM_PID: "weight-streamer"})
    snap = obs.registry.snapshot()
    for hists in snap["histograms"].values():
        for h in hists:
            hist_rows.append(_hist_row(f"{app}/wall", "wall", "demand_stall_s",
                                       h["labels"], h))
    return path, problems


def virtual_run(app: str, out_dir: str, hist_rows: list,
                calibration=None) -> tuple[str, list[str]]:
    """One deterministic replay of the app's recorded trace with a span
    tracer on the virtual clock; returns (trace path, problems)."""
    wl = _catalog()[app]
    client, _root, traces = record_workload(wl, runs=2)
    reg = client.logic_module.registered[wl.name]
    predictor = make_pos_predictor("static-capre", config=SessionConfig(rop_depth=2))
    predictor.warm(traces[0].accesses)
    tracer = Tracer(session=f"{app}-replay")
    result = replay(traces[-1], predictor, client.store, reg, dispatch="batch",
                    tracer=tracer, calibration=calibration)
    spans = tracer.spans()
    problems = _validate(f"{app}/virtual", spans, clock="virtual")
    path = os.path.join(out_dir, f"{app}_replay.trace.json")
    if not problems:
        write_chrome_trace(path, spans, clock="virtual",
                           instants=tracer.instants())
    hist_rows.append(_hist_row(f"{app}/virtual", "virtual", "stall_s", {"app": app}, {
        "count": result.evaluated, "sum": result.stall_seconds,
        "p50": result.stall_p50_s, "p99": result.stall_p99_s,
        "p999": result.stall_p999_s,
    }))
    return path, problems


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--apps", default="bank,oo7",
                    help="comma-separated catalog apps to replay (the first "
                         "also gets a live wall-clock run)")
    ap.add_argument("--out", default=os.path.join("artifacts", "obs"))
    args = ap.parse_args(argv)
    apps = [a for a in args.apps.split(",") if a]
    os.makedirs(args.out, exist_ok=True)
    calibration = load_calibration()
    hist_rows: list[dict] = []
    problems: list[str] = []
    path, p = wall_run(apps[0], args.out, hist_rows)
    problems += p
    if not p:
        print(f"wall timeline: {path}")
    for app in apps:
        path, p = virtual_run(app, args.out, hist_rows, calibration=calibration)
        problems += p
        if not p:
            print(f"virtual timeline: {path}")
    hist_path = os.path.join(args.out, "histograms.csv")
    with open(hist_path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=list(hist_rows[0].keys()))
        writer.writeheader()
        writer.writerows(hist_rows)
    print(f"histograms: {hist_path} ({len(hist_rows)} rows)")
    if problems:
        print("TIMELINE VALIDATION FAILED:")
        for msg in problems:
            print(f"  {msg}")
        return 1
    print(f"timeline validation: ok ({len(apps)} virtual + 1 wall)")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
