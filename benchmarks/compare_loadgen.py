"""Regression gate over the multi-tenant loadgen artifact: compare a fresh
``loadgen.csv`` (wall legs from ``benchmarks.loadgen``, virtual legs from
``repro.predict.evaluate --tenants N``) against the committed baseline and
fail when a tenant-count's tail latency regressed.

Gating logic, per baseline ``(clock, tenants, arrival, dispatch, mode)``
group:

  * the group must still exist in the fresh file with the same tenant
    count (a matrix leg silently dropping out is a regression, not a
    skip) and its ``ALL`` row must carry a populated ``fairness_ratio``;
  * the *worst per-tenant* ``stall_p99_s`` may not exceed the baseline's
    worst by more than the clock's headroom — virtual rows replay a
    deterministic clock so they get the tight bound (``--tolerance``,
    default 15% relative), wall rows run real threads on shared CI
    runners so they get ``--wall-tolerance`` (default 3x) plus an
    absolute floor under which noise is never a failure;
  * per-tenant ``evicted_before_use`` + ``admission_shed`` columns must
    be present and populated (the interference/back-pressure accounting
    going blind fails the gate even if latency looks fine).

Usage:
  PYTHONPATH=src python -m benchmarks.compare_loadgen fresh.csv baseline.csv
"""

from __future__ import annotations

import argparse
import csv
import sys

from repro.predict.loadsim import LOADGEN_COLUMNS

#: below this absolute p99 (seconds), differences are scheduler noise, not
#: regressions — never fail on them (wall rows; virtual floor is tighter)
P99_ABS_FLOOR_S = {"wall": 5e-3, "virtual": 1e-4}


def _read(path: str) -> list[dict]:
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    if not rows:
        sys.exit(f"error: {path} is empty")
    missing = [c for c in LOADGEN_COLUMNS if c not in rows[0]]
    if missing:
        sys.exit(f"error: {path} lacks columns {missing} — the harness "
                 f"schema drifted without a baseline update")
    return rows


def _key(row: dict) -> tuple:
    return (row["clock"], row["tenants"], row["arrival"],
            row["dispatch"], row["mode"])


def _groups(rows: list[dict]) -> dict[tuple, list[dict]]:
    out: dict[tuple, list[dict]] = {}
    for row in rows:
        out.setdefault(_key(row), []).append(row)
    return out


def _worst_p99(group: list[dict]) -> float:
    vals = [float(r["stall_p99_s"]) for r in group
            if r["tenant"] != "ALL" and r["stall_p99_s"] != ""]
    return max(vals) if vals else 0.0


def compare(fresh_rows: list[dict], base_rows: list[dict],
            tolerance: float, wall_tolerance: float,
            subset: bool = False) -> list[str]:
    problems: list[str] = []
    fresh = _groups(fresh_rows)
    gated = 0
    for key, base_group in _groups(base_rows).items():
        clock, tenants, arrival, dispatch, mode = key
        label = (f"{clock}/tenants={tenants}/arrival={arrival}"
                 f"/dispatch={dispatch}/mode={mode}")
        fresh_group = fresh.get(key)
        if fresh_group is None:
            if subset:
                # a CI matrix leg regenerates only its own tenant count;
                # the other legs gate the remaining baseline groups
                print(f"{label}: not in this leg, skipped")
                continue
            problems.append(f"{label}: leg missing from fresh file")
            continue
        gated += 1
        n_base = sum(1 for r in base_group if r["tenant"] != "ALL")
        n_fresh = sum(1 for r in fresh_group if r["tenant"] != "ALL")
        if n_fresh != n_base:
            problems.append(f"{label}: tenant rows {n_base} -> {n_fresh}")
        agg = [r for r in fresh_group if r["tenant"] == "ALL"]
        if not agg or agg[0]["fairness_ratio"] in ("", None):
            problems.append(f"{label}: ALL row lost its fairness_ratio")
        for col in ("evicted_before_use", "admission_shed"):
            if any(r[col] in ("", None) for r in fresh_group
                   if r["tenant"] != "ALL"):
                problems.append(f"{label}: per-tenant {col} went blank")
        base_p99 = _worst_p99(base_group)
        fresh_p99 = _worst_p99(fresh_group)
        headroom = wall_tolerance if clock == "wall" else 1.0 + tolerance
        floor = P99_ABS_FLOOR_S.get(clock, 0.0)
        limit = max(base_p99 * headroom, floor)
        status = "ok" if fresh_p99 <= limit else "REGRESSED"
        print(f"{label}: worst-tenant p99 {base_p99:.6f}s -> {fresh_p99:.6f}s "
              f"(limit {limit:.6f}s) {status}")
        if fresh_p99 > limit:
            problems.append(
                f"{label}: worst-tenant p99 {fresh_p99:.6f}s exceeds "
                f"{limit:.6f}s (baseline {base_p99:.6f}s x{headroom:.2f})")
    if not gated:
        problems.append("no baseline group matched the fresh file at all — "
                        "nothing was gated (wrong files?)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh")
    ap.add_argument("baseline")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="relative p99 headroom for virtual (deterministic) "
                         "rows")
    ap.add_argument("--wall-tolerance", type=float, default=3.0,
                    help="multiplicative p99 headroom for wall rows "
                         "(shared CI runners are noisy)")
    ap.add_argument("--subset", action="store_true",
                    help="the fresh file covers only some baseline legs "
                         "(a CI matrix job); skip the others instead of "
                         "failing on them")
    args = ap.parse_args(argv)
    problems = compare(_read(args.fresh), _read(args.baseline),
                       args.tolerance, args.wall_tolerance,
                       subset=args.subset)
    if problems:
        print(f"\nFAIL: {len(problems)} problem(s)", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print("\nOK: loadgen tails within baseline headroom")
    return 0


if __name__ == "__main__":
    sys.exit(main())
