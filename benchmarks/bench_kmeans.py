"""K-Means benchmark — paper Figure 14 (ROP has no single associations to
prefetch; CAPre prefetches the vector collections in parallel, gains come
from the first, cold, iteration)."""

from __future__ import annotations

from repro.apps.kmeans import build_kmeans_app, initial_centroids, populate_kmeans

from .common import MODES_SHORT, BenchResult, run_modes


def run(reps: int = 3, sizes=(400, 1200)) -> list[BenchResult]:
    results = []
    for n in sizes:
        cents = initial_centroids(k=4, dims=10)
        results += run_modes(
            "kmeans",
            f"n{n}",
            build_kmeans_app,
            lambda store, n=n: populate_kmeans(store, n_vectors=n, dims=10),
            lambda s, root: s.execute(root, "run", [list(c) for c in cents]),
            modes=MODES_SHORT,
            reps=reps,
        )
    return results
