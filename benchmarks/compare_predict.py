"""Regression gate over the offline replay artifact: compare a freshly
generated ``artifacts/predict/replay.csv`` against the committed baseline
and fail if prediction timeliness regressed (the ``benchmarks/compare.py``
of the prediction subsystem).

The replay engine is fully deterministic (virtual clock, no real threads in
the scoring loop), so equality-modulo-tolerance is a meaningful check:

  * every baseline (app, workload, predictor, cache_capacity) row must
    still exist in the fresh file with a populated ``timely_coverage`` —
    a predictor falling out of the registry or an app out of the sweep is
    itself a regression, not a skip;
  * per row, ``timely_coverage`` must not drop more than ``--tolerance``
    (default 0.02) below the baseline — static-capre is the headline (the
    paper's claim), but every predictor is held to its baseline so a
    regression in a *baseline's* scoring is caught too;
  * ``stall_saved_pct`` is reported alongside for context (not gated:
    it is derived from the same clock, gating both would double-count).

Usage: PYTHONPATH=src python -m benchmarks.compare_predict \
    artifacts/predict/replay.csv artifacts/predict/baseline.csv [--tolerance 0.02]
"""

from __future__ import annotations

import csv
import sys

Key = tuple[str, str, str, str]  # (app, workload, predictor, cache_capacity)


def _load(path: str) -> dict[Key, dict]:
    with open(path, newline="") as f:
        rows = list(csv.DictReader(f))
    return {
        (r["app"], r["workload"], r["predictor"], r["cache_capacity"]): r for r in rows
    }


def compare(current_path: str, baseline_path: str, tolerance: float = 0.02) -> list[str]:
    """Returns a list of human-readable regression messages (empty = pass)."""
    current, baseline = _load(current_path), _load(baseline_path)
    failures: list[str] = []
    for key in sorted(baseline):
        app, workload, predictor, cap = key
        label = f"{app}/{workload}/{predictor}@cache={cap}"
        base_tc = baseline[key].get("timely_coverage")
        if not base_tc:
            continue  # baseline never scored this row; nothing to hold it to
        cur = current.get(key)
        if cur is None:
            failures.append(f"{label}: row missing from {current_path}")
            continue
        cur_tc = cur.get("timely_coverage")
        if not cur_tc:
            failures.append(f"{label}: timely_coverage cell is empty in {current_path}")
            continue
        cur_f, base_f = float(cur_tc), float(base_tc)
        if cur_f < base_f - tolerance:
            failures.append(
                f"{label}: timely_coverage {cur_f:.3f} < baseline {base_f:.3f} "
                f"- {tolerance} (stall_saved {cur.get('stall_saved_pct')}% vs "
                f"{baseline[key].get('stall_saved_pct')}%)"
            )
    return failures


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly generated replay.csv")
    ap.add_argument("baseline", help="committed baseline.csv")
    ap.add_argument("--tolerance", type=float, default=0.02)
    args = ap.parse_args(argv)
    failures = compare(args.current, args.baseline, tolerance=args.tolerance)
    if failures:
        print("PREDICTION TIMELINESS REGRESSION:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    cur = _load(args.current)
    for (app, workload, pred, cap), r in sorted(cur.items()):
        if pred == "static-capre":
            print(f"ok {app}/{workload}/static-capre@cache={cap}: "
                  f"timely_coverage={r['timely_coverage']} stall_saved={r['stall_saved_pct']}%")
    print(f"prediction timeliness: {len(cur)} rows within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
