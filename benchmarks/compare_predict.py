"""Regression gate over the offline replay artifact: compare a freshly
generated ``artifacts/predict/replay.csv`` against the committed baseline
and fail if prediction timeliness regressed (the ``benchmarks/compare.py``
of the prediction subsystem).

The replay engine is fully deterministic (virtual clock, no real threads in
the scoring loop), so equality-modulo-tolerance is a meaningful check:

  * every baseline (app, workload, predictor, cache_capacity) row must
    still exist in the fresh file with a populated ``timely_coverage`` —
    a predictor falling out of the registry or an app out of the sweep is
    itself a regression, not a skip;
  * per row, ``timely_coverage`` must not drop more than ``--tolerance``
    (default 0.02) below the baseline — static-capre is the headline (the
    paper's claim), but every predictor is held to its baseline so a
    regression in a *baseline's* scoring is caught too;
  * ``stall_saved_pct`` is reported alongside for context (not gated:
    it is derived from the same clock, gating both would double-count);
  * the write-path columns (``writes``, ``write_hits``, ``dirty_evictions``,
    ``flushed_writes``) must be present in the fresh header, and any
    baseline row that charged writes must keep a populated ``writes`` cell
    — a harness that silently went write-blind fails the gate.

Usage: PYTHONPATH=src python -m benchmarks.compare_predict \
    artifacts/predict/replay.csv artifacts/predict/baseline.csv [--tolerance 0.02]
"""

from __future__ import annotations

import csv
import sys

Key = tuple[str, str, str, str]  # (app, workload, predictor, cache_capacity)

#: the write-path columns the v2 trace schema added — a replay.csv missing
#: them was produced by a pre-write-path harness and must fail the gate
WRITE_COLUMNS = ("writes", "write_hits", "dirty_evictions", "flushed_writes")


def _load(path: str) -> tuple[dict[Key, dict], list[str]]:
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        rows = list(reader)
        fields = list(reader.fieldnames or [])
    return (
        {(r["app"], r["workload"], r["predictor"], r["cache_capacity"]): r for r in rows},
        fields,
    )


def compare(current_path: str, baseline_path: str, tolerance: float = 0.02) -> list[str]:
    """Returns a list of human-readable regression messages (empty = pass)."""
    (current, cur_fields), (baseline, _) = _load(current_path), _load(baseline_path)
    failures: list[str] = []
    missing_cols = [c for c in WRITE_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: write-path columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    for key in sorted(baseline):
        app, workload, predictor, cap = key
        label = f"{app}/{workload}/{predictor}@cache={cap}"
        base_tc = baseline[key].get("timely_coverage")
        if not base_tc:
            continue  # baseline never scored this row; nothing to hold it to
        cur = current.get(key)
        if cur is None:
            failures.append(f"{label}: row missing from {current_path}")
            continue
        cur_tc = cur.get("timely_coverage")
        if not cur_tc:
            failures.append(f"{label}: timely_coverage cell is empty in {current_path}")
            continue
        cur_f, base_f = float(cur_tc), float(base_tc)
        if cur_f < base_f - tolerance:
            failures.append(
                f"{label}: timely_coverage {cur_f:.3f} < baseline {base_f:.3f} "
                f"- {tolerance} (stall_saved {cur.get('stall_saved_pct')}% vs "
                f"{baseline[key].get('stall_saved_pct')}%)"
            )
        # the mutating rows must keep reporting the write path: a baseline
        # row that charged writes cannot silently go write-blind
        if baseline[key].get("writes") and not cur.get("writes"):
            failures.append(f"{label}: writes cell is empty in {current_path}")
    return failures


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly generated replay.csv")
    ap.add_argument("baseline", help="committed baseline.csv")
    ap.add_argument("--tolerance", type=float, default=0.02)
    args = ap.parse_args(argv)
    failures = compare(args.current, args.baseline, tolerance=args.tolerance)
    if failures:
        print("PREDICTION TIMELINESS REGRESSION:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    cur, _ = _load(args.current)
    for (app, workload, pred, cap), r in sorted(cur.items()):
        if pred == "static-capre":
            print(f"ok {app}/{workload}/static-capre@cache={cap}: "
                  f"timely_coverage={r['timely_coverage']} stall_saved={r['stall_saved_pct']}%")
    print(f"prediction timeliness: {len(cur)} rows within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
