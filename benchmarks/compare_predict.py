"""Regression gate over the offline replay artifact: compare a freshly
generated ``artifacts/predict/replay.csv`` against the committed baseline
and fail if prediction timeliness regressed (the ``benchmarks/compare.py``
of the prediction subsystem).

The replay engine is fully deterministic (virtual clock, no real threads in
the scoring loop), so equality-modulo-tolerance is a meaningful check:

  * every baseline (app, workload, predictor, cache_capacity) row must
    still exist in the fresh file with a populated ``timely_coverage`` —
    a predictor falling out of the registry or an app out of the sweep is
    itself a regression, not a skip;
  * per row, ``timely_coverage`` must not drop more than ``--tolerance``
    (default 0.02) below the baseline — static-capre is the headline (the
    paper's claim), but every predictor is held to its baseline so a
    regression in a *baseline's* scoring is caught too;
  * ``stall_saved_pct`` is reported alongside for context (not gated:
    it is derived from the same clock, gating both would double-count);
  * the write-path columns (``writes``, ``write_hits``, ``dirty_evictions``,
    ``flushed_writes``) must be present in the fresh header, and any
    baseline row that charged writes must keep a populated ``writes`` cell
    — a harness that silently went write-blind fails the gate;
  * rows are keyed per eviction policy too (``policy`` column; a pre-policy
    file without the column reads as all-``lru``), and the fresh header
    must carry the policy columns (``policy``, ``protected_evictions``) —
    a harness that silently dropped the policy sweep fails the gate;
  * rows are keyed per dispatch mode as well (``dispatch`` column; a
    pre-batching file reads as all-``per-oid``), and the fresh header must
    carry the dispatch columns (``dispatch``, ``batch_dispatches``,
    ``dedup_suppressed``) — both dispatch modes are gated so neither the
    batched path nor the per-oid reference can silently regress.

``--update-baseline`` regenerates the committed baseline in place from the
fresh file — required in the same PR as any intentional column or metric
change (see DESIGN.md section 3.5: the baseline must be regenerated
whenever ``ReplayResult`` columns change).  It refuses to *shrink* the
gate: a fresh file missing rows the old baseline guarded (a partial sweep
promoted by accident) fails unless ``--force`` says the drop is meant.

Usage: PYTHONPATH=src python -m benchmarks.compare_predict \
    artifacts/predict/replay.csv artifacts/predict/baseline.csv \
    [--tolerance 0.02] [--update-baseline]
"""

from __future__ import annotations

import csv
import sys

# (app, workload, predictor, cache_capacity, policy, dispatch)
Key = tuple[str, str, str, str, str, str]

#: the write-path columns the v2 trace schema added — a replay.csv missing
#: them was produced by a pre-write-path harness and must fail the gate
WRITE_COLUMNS = ("writes", "write_hits", "dirty_evictions", "flushed_writes")

#: the eviction-policy columns — a replay.csv missing them was produced by
#: a pre-policy harness (hard-coded LRU) and must fail the gate
POLICY_COLUMNS = ("policy", "protected_evictions")

#: the dispatch columns — a replay.csv missing them was produced before the
#: batched dispatch layer existed (per-oid only) and must fail the gate
DISPATCH_COLUMNS = ("dispatch", "batch_dispatches", "dedup_suppressed")


def _load(path: str) -> tuple[dict[Key, dict], list[str]]:
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        rows = list(reader)
        fields = list(reader.fieldnames or [])
    return (
        {
            (r["app"], r["workload"], r["predictor"], r["cache_capacity"],
             r.get("policy") or "lru", r.get("dispatch") or "per-oid"): r
            for r in rows
        },
        fields,
    )


def compare(current_path: str, baseline_path: str, tolerance: float = 0.02) -> list[str]:
    """Returns a list of human-readable regression messages (empty = pass)."""
    (current, cur_fields), (baseline, _) = _load(current_path), _load(baseline_path)
    failures: list[str] = []
    missing_cols = [c for c in WRITE_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: write-path columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in POLICY_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: eviction-policy columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in DISPATCH_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: dispatch columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    for key in sorted(baseline):
        app, workload, predictor, cap, policy, dispatch = key
        label = f"{app}/{workload}/{predictor}@cache={cap}/{policy}/{dispatch}"
        base_tc = baseline[key].get("timely_coverage")
        if not base_tc:
            continue  # baseline never scored this row; nothing to hold it to
        cur = current.get(key)
        if cur is None:
            failures.append(f"{label}: row missing from {current_path}")
            continue
        cur_tc = cur.get("timely_coverage")
        if not cur_tc:
            failures.append(f"{label}: timely_coverage cell is empty in {current_path}")
            continue
        cur_f, base_f = float(cur_tc), float(base_tc)
        if cur_f < base_f - tolerance:
            failures.append(
                f"{label}: timely_coverage {cur_f:.3f} < baseline {base_f:.3f} "
                f"- {tolerance} (stall_saved {cur.get('stall_saved_pct')}% vs "
                f"{baseline[key].get('stall_saved_pct')}%)"
            )
        # the mutating rows must keep reporting the write path: a baseline
        # row that charged writes cannot silently go write-blind
        if baseline[key].get("writes") and not cur.get("writes"):
            failures.append(f"{label}: writes cell is empty in {current_path}")
    return failures


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly generated replay.csv")
    ap.add_argument("baseline", help="committed baseline.csv")
    ap.add_argument("--tolerance", type=float, default=0.02)
    ap.add_argument("--update-baseline", action="store_true",
                    help="regenerate the committed baseline in place from the "
                         "fresh file instead of comparing (use in the PR that "
                         "intentionally changes columns or metrics)")
    ap.add_argument("--force", action="store_true",
                    help="with --update-baseline: allow the new baseline to "
                         "drop rows the old one guarded")
    args = ap.parse_args(argv)
    if args.update_baseline:
        import os
        import shutil

        cur, _ = _load(args.current)
        if os.path.exists(args.baseline) and not args.force:
            old, _ = _load(args.baseline)
            dropped = sorted(set(old) - set(cur))
            if dropped:
                print("refusing to shrink the baseline — these rows would lose "
                      "gate coverage (run the full CI sweep, or pass --force "
                      "to drop them deliberately):")
                for key in dropped:
                    print(f"  {'/'.join(key)}")
                return 1
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline regenerated: {args.baseline} <- {args.current} ({len(cur)} rows)")
        return 0
    failures = compare(args.current, args.baseline, tolerance=args.tolerance)
    if failures:
        print("PREDICTION TIMELINESS REGRESSION:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    cur, _ = _load(args.current)
    for (app, workload, pred, cap, policy, dispatch), r in sorted(cur.items()):
        if pred == "static-capre":
            print(f"ok {app}/{workload}/static-capre@cache={cap}/{policy}/{dispatch}: "
                  f"timely_coverage={r['timely_coverage']} stall_saved={r['stall_saved_pct']}%")
    print(f"prediction timeliness: {len(cur)} rows within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
