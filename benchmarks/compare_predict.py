"""Regression gate over the offline replay artifact: compare a freshly
generated ``artifacts/predict/replay.csv`` against the committed baseline
and fail if prediction timeliness regressed (the ``benchmarks/compare.py``
of the prediction subsystem).

The replay engine is fully deterministic (virtual clock, no real threads in
the scoring loop), so equality-modulo-tolerance is a meaningful check:

  * every baseline (app, workload, predictor, cache_capacity) row must
    still exist in the fresh file with a populated ``timely_coverage`` —
    a predictor falling out of the registry or an app out of the sweep is
    itself a regression, not a skip;
  * per row, ``timely_coverage`` must not drop more than ``--tolerance``
    (default 0.02) below the baseline — static-capre is the headline (the
    paper's claim), but every predictor is held to its baseline so a
    regression in a *baseline's* scoring is caught too;
  * ``stall_saved_pct`` is reported alongside for context (not gated:
    it is derived from the same clock, gating both would double-count);
  * the write-path columns (``writes``, ``write_hits``, ``dirty_evictions``,
    ``flushed_writes``) must be present in the fresh header, and any
    baseline row that charged writes must keep a populated ``writes`` cell
    — a harness that silently went write-blind fails the gate;
  * rows are keyed per eviction policy too (``policy`` column; a pre-policy
    file without the column reads as all-``lru``), and the fresh header
    must carry the policy columns (``policy``, ``protected_evictions``) —
    a harness that silently dropped the policy sweep fails the gate;
  * rows are keyed per dispatch mode as well (``dispatch`` column; a
    pre-batching file reads as all-``per-oid``), and the fresh header must
    carry the dispatch columns (``dispatch``, ``batch_dispatches``,
    ``dedup_suppressed``) — both dispatch modes are gated so neither the
    batched path nor the per-oid reference can silently regress;
  * the per-operation stall-percentile columns (``stall_p50_s``,
    ``stall_p99_s``, ``stall_p999_s``, plus the calibrated-seconds pair)
    must be present, and per row the fresh ``stall_p99_s`` may not exceed
    the baseline tail by more than ``--p99-tolerance`` relative headroom
    (absolute floor ``P99_ABS_FLOOR_S``) — mean stall can stay flat while
    the tail quietly doubles; this gate catches that;
  * the static-optimizer columns (``rfo_prefetches``, ``truncated_hints``,
    ``hint_priority_mean``, ``ownership_upgrades``, ``exec_delayed``) must
    be present in the fresh header — a harness that silently dropped the
    optimizer passes (RFO dirty-allocation, partial-traversal truncation,
    priority-ranked dispatch, executor-pool modeling) fails the gate.

``--update-baseline`` regenerates the committed baseline in place from the
fresh file — required in the same PR as any intentional column or metric
change (see DESIGN.md section 3.5: the baseline must be regenerated
whenever ``ReplayResult`` columns change).  It refuses to *shrink* the
gate: a fresh file missing rows the old baseline guarded (a partial sweep
promoted by accident) fails unless ``--force`` says the drop is meant.

Usage: PYTHONPATH=src python -m benchmarks.compare_predict \
    artifacts/predict/replay.csv artifacts/predict/baseline.csv \
    [--tolerance 0.02] [--update-baseline]
"""

from __future__ import annotations

import csv
import sys

# (app, workload, predictor, cache_capacity, policy, dispatch)
Key = tuple[str, str, str, str, str, str]

#: the write-path columns the v2 trace schema added — a replay.csv missing
#: them was produced by a pre-write-path harness and must fail the gate
WRITE_COLUMNS = ("writes", "write_hits", "dirty_evictions", "flushed_writes")

#: the eviction-policy columns — a replay.csv missing them was produced by
#: a pre-policy harness (hard-coded LRU) and must fail the gate
POLICY_COLUMNS = ("policy", "protected_evictions")

#: the dispatch columns — a replay.csv missing them was produced before the
#: batched dispatch layer existed (per-oid only) and must fail the gate
DISPATCH_COLUMNS = ("dispatch", "batch_dispatches", "dedup_suppressed")

#: the per-operation stall-percentile columns (exact over the virtual
#: clock's demand events) plus the calibrated-seconds report — a replay.csv
#: missing them was produced by a pre-observability harness and must fail
#: the gate; ``stall_p99_s`` is additionally gated against regression
PCTL_COLUMNS = ("stall_p50_s", "stall_p99_s", "stall_p999_s",
                "calib_scale", "calibrated_stall_s")

#: the placement/failure-scenario columns — a replay.csv missing them was
#: produced before placement became a policy (ISSUE 7) and must fail the
#: gate; only clean-regime rows (no-fault, round-robin, replication 1) are
#: compared against the baseline, which is recorded in that regime
PLACEMENT_COLUMNS = ("placement", "replication", "scenario", "failovers")

#: the static-optimizer columns — a replay.csv missing them was produced
#: before the hint optimizer existed (ISSUE 8: RFO write-set projection,
#: partial-traversal truncation, cost-ranked dispatch, modeled executor
#: saturation) and must fail the gate
OPT_COLUMNS = ("rfo_prefetches", "truncated_hints", "hint_priority_mean",
               "ownership_upgrades", "exec_delayed")

#: the partition-tolerant recovery columns (ISSUE 10: write quorums, hedged
#: reads, readmission + anti-entropy resync) — a replay.csv missing them
#: was produced by a pre-recovery harness and must fail the gate
RECOVERY_COLUMNS = ("write_quorum", "readmissions", "resync_lines",
                    "hedged_reads", "hedge_wins", "quorum_writes",
                    "quorum_acks", "quorum_retries", "quorum_failures")

#: p99 stall gating: fail when the fresh tail exceeds the baseline by more
#: than ``rel`` (fractional) with an absolute floor of ``abs`` seconds —
#: the floor keeps sub-millisecond tails from tripping on exact-arithmetic
#: jitter introduced by intentional think/overhead constant tweaks
P99_REL_TOLERANCE = 0.10
P99_ABS_FLOOR_S = 5e-4


def _clean_regime(r: dict) -> bool:
    """Only the clean regime is gated: a file carrying fault-scenario or
    exotic-placement rows (bench_placement sweeps) must not let those rows
    shadow the no-fault/round-robin cells the baseline pins down.  Files
    from before the placement columns existed read as all-clean."""
    return (
        (r.get("scenario") or "no-fault") == "no-fault"
        and (r.get("placement") or "round-robin") == "round-robin"
        and (r.get("replication") or "1") == "1"
        and (r.get("write_quorum") or "1") == "1"
    )


def _load(path: str) -> tuple[dict[Key, dict], list[str]]:
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        rows = list(reader)
        fields = list(reader.fieldnames or [])
    return (
        {
            (r["app"], r["workload"], r["predictor"], r["cache_capacity"],
             r.get("policy") or "lru", r.get("dispatch") or "per-oid"): r
            for r in rows
            if _clean_regime(r)
        },
        fields,
    )


def compare(current_path: str, baseline_path: str, tolerance: float = 0.02,
            p99_tolerance: float = P99_REL_TOLERANCE) -> list[str]:
    """Returns a list of human-readable regression messages (empty = pass)."""
    (current, cur_fields), (baseline, _) = _load(current_path), _load(baseline_path)
    failures: list[str] = []
    missing_cols = [c for c in WRITE_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: write-path columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in POLICY_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: eviction-policy columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in DISPATCH_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: dispatch columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in PCTL_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: stall-percentile columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in PLACEMENT_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: placement/scenario columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in OPT_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: static-optimizer columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    missing_cols = [c for c in RECOVERY_COLUMNS if c not in cur_fields]
    if missing_cols:
        failures.append(
            f"{current_path}: recovery columns missing from header: "
            f"{', '.join(missing_cols)}"
        )
    for key in sorted(baseline):
        app, workload, predictor, cap, policy, dispatch = key
        label = f"{app}/{workload}/{predictor}@cache={cap}/{policy}/{dispatch}"
        base_tc = baseline[key].get("timely_coverage")
        if not base_tc:
            continue  # baseline never scored this row; nothing to hold it to
        cur = current.get(key)
        if cur is None:
            failures.append(f"{label}: row missing from {current_path}")
            continue
        cur_tc = cur.get("timely_coverage")
        if not cur_tc:
            failures.append(f"{label}: timely_coverage cell is empty in {current_path}")
            continue
        cur_f, base_f = float(cur_tc), float(base_tc)
        if cur_f < base_f - tolerance:
            failures.append(
                f"{label}: timely_coverage {cur_f:.3f} < baseline {base_f:.3f} "
                f"- {tolerance} (stall_saved {cur.get('stall_saved_pct')}% vs "
                f"{baseline[key].get('stall_saved_pct')}%)"
            )
        # the mutating rows must keep reporting the write path: a baseline
        # row that charged writes cannot silently go write-blind
        if baseline[key].get("writes") and not cur.get("writes"):
            failures.append(f"{label}: writes cell is empty in {current_path}")
        # tail-latency gate: the p99 per-operation stall must not grow past
        # the baseline tail by more than p99_tolerance (relative), with an
        # absolute floor so near-zero tails don't trip on harmless jitter
        base_p99, cur_p99 = baseline[key].get("stall_p99_s"), cur.get("stall_p99_s")
        if base_p99 and cur_p99:
            base_f, cur_f = float(base_p99), float(cur_p99)
            allowed = max(base_f * (1.0 + p99_tolerance), base_f + P99_ABS_FLOOR_S)
            if cur_f > allowed:
                failures.append(
                    f"{label}: stall_p99_s {cur_f:.6f} > baseline {base_f:.6f} "
                    f"(+{p99_tolerance:.0%} rel / +{P99_ABS_FLOOR_S}s abs)"
                )
    return failures


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly generated replay.csv")
    ap.add_argument("baseline", help="committed baseline.csv")
    ap.add_argument("--tolerance", type=float, default=0.02)
    ap.add_argument("--p99-tolerance", type=float, default=P99_REL_TOLERANCE,
                    help="relative headroom allowed on stall_p99_s before the "
                         "tail-latency gate fails (absolute floor "
                         f"{P99_ABS_FLOOR_S}s always applies)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="regenerate the committed baseline in place from the "
                         "fresh file instead of comparing (use in the PR that "
                         "intentionally changes columns or metrics)")
    ap.add_argument("--force", action="store_true",
                    help="with --update-baseline: allow the new baseline to "
                         "drop rows the old one guarded")
    args = ap.parse_args(argv)
    if args.update_baseline:
        import os
        import shutil

        cur, _ = _load(args.current)
        if os.path.exists(args.baseline) and not args.force:
            old, _ = _load(args.baseline)
            dropped = sorted(set(old) - set(cur))
            if dropped:
                print("refusing to shrink the baseline — these rows would lose "
                      "gate coverage (run the full CI sweep, or pass --force "
                      "to drop them deliberately):")
                for key in dropped:
                    print(f"  {'/'.join(key)}")
                return 1
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline regenerated: {args.baseline} <- {args.current} ({len(cur)} rows)")
        return 0
    failures = compare(args.current, args.baseline, tolerance=args.tolerance,
                       p99_tolerance=args.p99_tolerance)
    if failures:
        print("PREDICTION TIMELINESS REGRESSION:")
        for msg in failures:
            print(f"  {msg}")
        return 1
    cur, _ = _load(args.current)
    for (app, workload, pred, cap, policy, dispatch), r in sorted(cur.items()):
        if pred == "static-capre":
            print(f"ok {app}/{workload}/static-capre@cache={cap}/{policy}/{dispatch}: "
                  f"timely_coverage={r['timely_coverage']} stall_saved={r['stall_saved_pct']}%")
    print(f"prediction timeliness: {len(cur)} rows within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
