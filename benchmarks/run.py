"""Benchmark driver: one section per paper table/figure, printing
``name,us_per_call,derived`` CSV lines.

Sections:
  * oo7 t1/t2b        — Figure 10
  * wordcount         — Figure 12
  * kmeans            — Figure 14
  * pga dfs/bf        — Figure 16
  * analysis time     — Table 4 / Figure 8
  * branch-dep corpus — Table 2
  * streaming         — the TPU adaptation (CAPre-plan vs ROP-depth weight
                        streaming; see benchmarks/bench_streaming.py)
  * predictors        — every registered prediction strategy head-to-head
                        (static / schema / trace-mined / hybrid; see
                        benchmarks/bench_predictors.py)

Environment: REPRO_BENCH_REPS (default 3), REPRO_BENCH_FAST=1 shrinks sizes.
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    reps = int(os.environ.get("REPRO_BENCH_REPS", "3"))
    fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"

    from . import bench_analysis_time, bench_kmeans, bench_oo7, bench_pga, bench_wordcount
    from .common import print_results

    print("name,us_per_call,derived")

    results = []
    results += bench_oo7.bench_t1(reps=reps, sizes=("small",) if fast else ("small", "medium"))
    results += bench_oo7.bench_t2b(reps=reps)
    results += bench_wordcount.run(reps=reps, chunk_sweep=(16, 64) if fast else (16, 64, 256))
    results += bench_kmeans.run(reps=reps, sizes=(400,) if fast else (400, 1200))
    results += bench_pga.run(reps=reps, n_vertices=200 if fast else 400)

    from . import bench_predictors

    predictor_results = bench_predictors.run(
        reps=reps,
        apps=("bank",) if fast else ("bank", "wordcount", "kmeans"),
        cache_capacities=(0,) if fast else (0, 64),
    )
    results += predictor_results
    print_results(results)
    # tracked artifact so prediction-quality regressions are visible across PRs
    bench_predictors.write_csv(predictor_results)
    sys.stdout.flush()

    for line in bench_analysis_time.run():
        print(line)

    try:
        from . import bench_streaming

        for line in bench_streaming.run():
            print(line)
    except ImportError:
        pass

    # roofline terms per (arch x shape) from the dry-run artifacts, if present
    try:
        from . import roofline

        for line in roofline.run():
            print(line)
    except Exception as e:  # artifacts may be absent on a fresh checkout
        print(f"roofline/skipped,0,{e!r}")


if __name__ == "__main__":
    main()
