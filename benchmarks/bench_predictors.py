"""End-to-end comparison of every registered prefetch predictor: wall-clock
execution time + live prefetch accuracy + predictor overhead, on the paper
benchmark apps (the companion to the offline replay tables of
``repro.predict.evaluate``).

For each (app, mode): a fresh store is populated, one *monitoring run*
records the access trace with prefetching off (the warm-up a trace-mined
predictor needs — its cost is what CAPre's zero-monitoring story avoids),
then ``reps`` cold-cache repetitions run with the mode's predictor live.

Usage: PYTHONPATH=src python -m benchmarks.bench_predictors [--fast]
"""

from __future__ import annotations

import statistics
import time

from repro.pos.client import POSClient
from repro.predict.evaluate import _catalog

from .common import BENCH_LATENCY, BenchResult, print_results

PREDICTOR_MODES = (
    ("none", None),
    ("rop_d2", "rop"),
    ("capre", "capre"),
    ("markov", "markov-miner"),
    ("hybrid", "hybrid"),
)


def run(reps: int = 3, apps=("bank", "wordcount", "kmeans"), modes=PREDICTOR_MODES,
        n_services: int = 4, parallel_workers: int = 16) -> list[BenchResult]:
    catalog = _catalog()
    results: list[BenchResult] = []
    for app_name in apps:
        wl = catalog[app_name]
        for mode_name, mode in modes:
            client = POSClient(n_services=n_services, latency=BENCH_LATENCY)
            client.register(wl.build_app())
            root = wl.populate(client.store)
            # monitoring run: record the trace the miners train on
            warm_trace = None
            if mode in ("markov-miner", "hybrid"):
                client.store.trace = []
                with client.session(wl.name, mode=None) as s:
                    wl.run_once(s, root)
                warm_trace = list(client.store.trace)
                client.store.trace = None
            times, metrics = [], {}
            for _ in range(reps):
                client.store.reset_runtime_state()
                with client.session(
                    wl.name,
                    mode=mode,
                    rop_depth=2,
                    parallel_workers=parallel_workers,
                    warm_trace=warm_trace,
                ) as s:
                    t0 = time.perf_counter()
                    wl.run_once(s, root)
                    times.append(time.perf_counter() - t0)
                    s.drain(30.0)
                    metrics = client.store.metrics.snapshot()
                    metrics.update(client.store.prefetch_accuracy())
                    if s.predictor is not None:
                        metrics.update(s.predictor.overhead.snapshot())
            results.append(
                BenchResult(
                    benchmark=f"predictors_{app_name}",
                    config=wl.workload,
                    mode=mode_name,
                    mean_s=statistics.mean(times),
                    stdev_s=statistics.stdev(times) if len(times) > 1 else 0.0,
                    reps=reps,
                    metrics=metrics,
                )
            )
    return results


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    apps = ("bank",) if args.fast else ("bank", "wordcount", "kmeans")
    results = run(reps=args.reps, apps=apps)
    print("name,us_per_call,derived")
    print_results(results)
    for r in results:
        acc = {k: r.metrics.get(k) for k in ("precision", "recall", "table_bytes", "monitor_events")}
        print(f"# {r.benchmark}/{r.mode}: {acc}")


if __name__ == "__main__":
    main()
