"""End-to-end comparison of every registered prefetch predictor: wall-clock
execution time + live prefetch accuracy + predictor overhead, on the paper
benchmark apps (the companion to the offline replay tables of
``repro.predict.evaluate``).

For each (app, mode, cache capacity): a fresh store is populated, one
*monitoring run* records the access trace with prefetching off (the warm-up
a trace-mined predictor needs — its cost is what CAPre's zero-monitoring
story avoids), then ``reps`` cold-cache repetitions run with the mode's
predictor live.  A bounded per-DS cache (``cache_capacities`` other than 0)
exposes prefetch thrashing: useless ROP reads evict objects the application
still needs.

Results are also written as a CSV artifact (``artifacts/predict/bench.csv``)
so wall-clock prediction-quality regressions are visible across PRs.

Usage: PYTHONPATH=src python -m benchmarks.bench_predictors [--fast]
"""

from __future__ import annotations

import os
import statistics
import time
import warnings

from repro.obs import Observability
from repro.pos.client import POSClient
from repro.predict.evaluate import _catalog

from repro.pos.latency import DEFAULT as DEFAULT_LATENCY

from .common import BENCH_LATENCY, BenchResult, print_results, timer_warm_keeper

PREDICTOR_MODES = (
    ("none", None),
    ("rop_d2", "rop"),
    ("capre", "capre"),
    ("markov", "markov-miner"),
    ("hybrid", "hybrid"),
)

#: oo7 joins the default sweep: its deep assembly fan-out is where batched
#: per-Data-Service dispatch shows the clearest wall-clock win over one
#: pool task per oid
DEFAULT_APPS = ("bank", "bank_write", "wordcount", "kmeans", "oo7")

DISPATCH_MODES = ("per-oid", "batch")


#: named latency models the CLI can bench under: "bench" is the historical
#: paper-table model (one disk arm per DS), "default" is pos.latency.DEFAULT
#: (4 arms per DS — the model the dispatch acceptance comparison uses)
LATENCIES = {"bench": BENCH_LATENCY, "default": DEFAULT_LATENCY}


def run(reps: int = 3, apps=DEFAULT_APPS, modes=PREDICTOR_MODES,
        n_services: int = 4, parallel_workers: int = 16,
        cache_capacities=(0,), policies=("lru",), shared_budget: bool = False,
        dispatch_modes=DISPATCH_MODES, latency=BENCH_LATENCY) -> list[BenchResult]:
    catalog = _catalog()
    results: list[BenchResult] = []
    with timer_warm_keeper():
        for app_name in apps:
            wl = catalog[app_name]
            for capacity in cache_capacities:
                for policy in policies:
                    _run_policy(results, wl, app_name, capacity, policy, shared_budget,
                                modes, reps, n_services, parallel_workers, dispatch_modes,
                                latency=latency)
    return results


def _run_policy(results, wl, app_name, capacity, policy, shared_budget,
                modes, reps, n_services, parallel_workers, dispatch_modes,
                latency=BENCH_LATENCY) -> None:
    """One (workload, capacity, policy) cell: bench every (mode, dispatch)
    on a live store running that eviction policy (optionally drawing on a
    shared global budget rather than per-service capacities).  The
    no-prefetch reference never dispatches, so it runs once per cell.

    Repetitions are **interleaved across dispatch modes** (rep k of every
    dispatch runs back-to-back before rep k+1 of any): the per-oid vs
    batch delta is the quantity this table exists to show, and on a shared
    box sequential cells pick up machine-load drift larger than the delta
    itself — pairing the reps in time cancels it."""
    for mode_name, mode in modes:
        sweeps = dispatch_modes if mode is not None else dispatch_modes[:1]
        cells = {}
        for dispatch in sweeps:
            client = POSClient(
                n_services=n_services, latency=latency, cache_capacity=capacity,
                cache_policy=policy, shared_budget=shared_budget,
            )
            # registry-only observability (no span tracing: the bench is the
            # "tracing disabled" regime the acceptance check holds to PR 5's
            # means) — per-service demand-stall histograms pool across reps,
            # and the meter reports what the instrumentation itself cost
            obs = Observability(tracing=False)
            client.store.attach_obs(obs)
            client.register(wl.build_app())
            root = wl.populate(client.store)
            # monitoring run: record the event trace the miners train
            # on (schema v2 — method entries, reads and writes; the
            # miners normalize to the demand-oid sequence themselves)
            warm_trace = None
            if mode in ("markov-miner", "hybrid"):
                client.store.trace = []
                with client.session(wl.name, mode=None) as s:
                    wl.run_once(s, root)
                warm_trace = list(client.store.trace)
                client.store.trace = None
            # drop whatever populate/monitoring charged — the histograms
            # should pool exactly the timed repetitions below
            obs.registry.reset()
            cells[dispatch] = (client, root, warm_trace, obs)
        times = {d: [] for d in sweeps}
        metrics_by = {d: {} for d in sweeps}
        for _ in range(reps):
            for dispatch in sweeps:
                client, root, warm_trace, _obs = cells[dispatch]
                client.store.reset_runtime_state()
                with client.session(
                    wl.name,
                    mode=mode,
                    rop_depth=2,
                    parallel_workers=parallel_workers,
                    warm_trace=warm_trace,
                    dispatch=dispatch,
                ) as s:
                    t0 = time.perf_counter()
                    wl.run_once(s, root)
                    times[dispatch].append(time.perf_counter() - t0)
                    if not s.drain(30.0):
                        # a silently ignored timeout here used to let
                        # straggler prefetch tasks pollute the next rep
                        warnings.warn(
                            f"{app_name}/{mode_name}: prefetch drain timed "
                            "out; metrics for this rep are incomplete",
                            RuntimeWarning,
                        )
                    metrics = client.store.snapshot_metrics()
                    live_counters = {
                        k: metrics[k]
                        for k in ("batch_dispatches", "dedup_suppressed",
                                  "rfo_prefetches")
                    }
                    # admission control lives on the session's runtime, not
                    # the store: read it before the session closes
                    live_counters["admission_dropped"] = (
                        s.runtime.stats()["admission_dropped"]
                    )
                    metrics.update(client.store.prefetch_accuracy())
                    metrics["evictions"] = sum(ds.evictions for ds in client.store.services)
                    if s.predictor is not None:
                        metrics.update(s.predictor.overhead.snapshot())
                    # after the ledger: the live counts live on the store
                    # (its policies / per-service counters), not the
                    # predictor's offline-only ledger slots
                    metrics["protected_evictions"] = client.store.protected_evictions()
                    metrics.update(live_counters)
                    metrics_by[dispatch] = metrics
        for dispatch in sweeps:
            metrics = metrics_by[dispatch]
            obs = cells[dispatch][3]
            # per-operation stall tail over all reps (bucketed estimate:
            # this is the wall-clock regime) + what observing it cost
            p50, p99, p999 = obs.registry.percentiles("demand_stall_s")
            metrics["stall_p50_s"] = "" if p50 is None else f"{p50:.6f}"
            metrics["stall_p99_s"] = "" if p99 is None else f"{p99:.6f}"
            metrics["stall_p999_s"] = "" if p999 is None else f"{p999:.6f}"
            metrics["obs_seconds"] = f"{obs.registry.meter.seconds:.6f}"
            metrics["obs_events"] = obs.registry.meter.events
            metrics["policy"] = policy
            metrics["dispatch"] = dispatch if mode is not None else ""
            metrics["workload"] = wl.workload
            metrics["cache_capacity"] = capacity
            # shared budget only exists at a bounded capacity (ObjectStore
            # builds no SharedBudget otherwise) — label what actually ran
            shared = shared_budget and bool(capacity)
            cfg = wl.workload if not capacity else f"{wl.workload}_c{capacity}"
            if policy != "lru" or shared:
                cfg = f"{cfg}_{policy}" + ("_shared" if shared else "")
            if mode is not None and dispatch != "batch":
                cfg = f"{cfg}_{dispatch}"
            results.append(
                BenchResult(
                    benchmark=f"predictors_{app_name}",
                    config=cfg,
                    mode=mode_name,
                    mean_s=statistics.mean(times[dispatch]),
                    stdev_s=(statistics.stdev(times[dispatch])
                             if len(times[dispatch]) > 1 else 0.0),
                    reps=reps,
                    metrics=metrics,
                )
            )


def write_csv(results: list[BenchResult], path: str = "artifacts/predict/bench.csv") -> str:
    """Flatten BenchResults (one row per app/config/mode, metrics inline)
    into the tracked artifact the regression check reads."""
    import csv

    metric_keys = sorted({k for r in results for k in r.metrics})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", newline="") as f:
        writer = csv.writer(f)
        writer.writerow(["benchmark", "config", "mode", "mean_s", "stdev_s", "reps", *metric_keys])
        for r in results:
            writer.writerow(
                [r.benchmark, r.config, r.mode, f"{r.mean_s:.6f}", f"{r.stdev_s:.6f}", r.reps]
                + [("" if r.metrics.get(k) is None else r.metrics.get(k, "")) for k in metric_keys]
            )
    return path


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--apps", default=",".join(DEFAULT_APPS),
                    help="comma-separated app names from the catalog")
    ap.add_argument("--cache-capacity", default="0",
                    help="comma-separated per-DS cache capacities to sweep (0 = unbounded)")
    ap.add_argument("--cache-policy", default="lru",
                    help="comma-separated eviction policies to sweep "
                         "(lru, fifo, clock, lfu, prefetch-aware)")
    ap.add_argument("--shared-budget", action="store_true",
                    help="treat --cache-capacity as one global line budget "
                         "shared by all Data Services")
    ap.add_argument("--dispatch", default=",".join(DISPATCH_MODES),
                    help="comma-separated prefetch dispatch modes to sweep "
                         "(per-oid, batch)")
    ap.add_argument("--latency", default="bench", choices=sorted(LATENCIES),
                    help="latency model: 'bench' (one disk arm per DS, the "
                         "historical paper tables) or 'default' "
                         "(pos.latency.DEFAULT, 4 arms per DS)")
    ap.add_argument("--csv", default="artifacts/predict/bench.csv",
                    help="CSV artifact path ('' disables)")
    args = ap.parse_args()
    apps = ("bank",) if args.fast else tuple(a for a in args.apps.split(",") if a)
    capacities = tuple(int(c) for c in args.cache_capacity.split(",") if c != "")
    policies = tuple(p for p in args.cache_policy.split(",") if p)
    dispatch_modes = tuple(d for d in args.dispatch.split(",") if d)
    results = run(reps=args.reps, apps=apps, cache_capacities=capacities,
                  policies=policies, shared_budget=args.shared_budget,
                  dispatch_modes=dispatch_modes, latency=LATENCIES[args.latency])
    print("name,us_per_call,derived")
    print_results(results)
    for r in results:
        acc = {k: r.metrics.get(k) for k in
               ("precision", "recall", "evictions", "table_bytes", "monitor_events")}
        print(f"# {r.benchmark}/{r.config}/{r.mode}: {acc}")
    if args.csv:
        print(f"# wrote {write_csv(results, args.csv)}")


if __name__ == "__main__":
    main()
