"""Wordcount benchmark — paper Figure 12 (chunk-count sweep: few large
objects vs many small objects)."""

from __future__ import annotations

from repro.apps.wordcount import build_wordcount_app, populate_wordcount

from .common import BenchResult, run_modes

MODES_WC = (
    ("none", None, 0),
    ("rop_d1", "rop", 1),
    ("rop_d3", "rop", 3),
    ("capre", "capre", 0),
)


def run(reps: int = 3, chunk_sweep=(16, 64, 256)) -> list[BenchResult]:
    results = []
    for chunks in chunk_sweep:
        results += run_modes(
            "wordcount",
            f"c{chunks}",
            build_wordcount_app,
            lambda store, c=chunks: populate_wordcount(
                store, chunks_per_text=c, words_per_chunk=max(4, 2048 // c)
            ),
            lambda s, root: s.execute(root, "run"),
            modes=MODES_WC,
            reps=reps,
        )
    return results
