"""Multi-tenant closed/open-loop load generator over one shared store.

The single-tenant benches answer "how much stall does prefetching hide";
this harness answers the multi-tenancy questions (DESIGN.md §3.10): when N
concurrent ``Session``s share one store's caches, disk queues and a PR 4
shared line budget, whose prefetches help whom?  Each tenant is a thread
driving one of the paper apps (heavy-tailed mix — most tenants run the
cheap traversals, a rare tail runs OO7) through a labeled session, so

  * per-tenant stall distributions come from the ``tenant_stall_s``
    registry histograms the labeled session pre-resolves,
  * per-tenant prefetch interference comes from lifecycle spans: a span
    that ends ``evicted`` is charged to the session that *scheduled* it
    (its working set was destroyed by the shared budget),
  * per-tenant shed counts come from each session's own
    ``PrefetchRuntime.admit`` accounting (``max_outstanding``
    back-pressure),

and the run emits the same ``loadgen.csv`` schema as the virtual-clock
mirror (``predict/evaluate.py --tenants N``), with ``clock=wall`` rows
carrying real elapsed seconds.  Arrival processes:

  * ``closed``        — each tenant re-submits after an exponential think,
  * ``poisson:RATE``  — open: job k starts at the tenant's k-th Poisson
    arrival (aggregate RATE jobs/s split evenly), or immediately after
    job k-1 if the system is running behind (queued arrivals).

Usage: PYTHONPATH=src python -m benchmarks.loadgen --tenants 16
"""

from __future__ import annotations

import argparse
import os
import random
import threading
import time

from repro.obs import Observability
from repro.pos.client import POSClient, Session, SessionConfig
from repro.predict.evaluate import _catalog
from repro.predict.loadsim import (
    DEFAULT_MIX,
    heavy_tailed_weights,
    parse_arrival,
    write_loadgen_csv,
)

from .common import BENCH_LATENCY, timer_warm_keeper


class _TenantRun:
    def __init__(self, idx: int, label: str, app_key: str):
        self.idx = idx
        self.label = label
        self.app_key = app_key
        self.jobs_done = 0
        self.shed = 0
        self.wall_s = 0.0
        self.error: str = ""


def _tenant_worker(client: POSClient, tn: _TenantRun, wl, root: int,
                   args, start_barrier: threading.Barrier,
                   start_t: list, arrivals: list[float],
                   think_rng: random.Random) -> None:
    reg = client.logic_module.registered[wl.name]
    cfg = SessionConfig(
        mode=args.mode, dispatch=args.dispatch,
        parallel_workers=args.workers, session_label=tn.label,
        max_outstanding=args.max_outstanding,
        admission_threshold=args.admission_threshold,
    )
    session = Session(client.store, reg, cfg)
    try:
        start_barrier.wait(timeout=30.0)
        t0 = time.perf_counter()
        for k in range(args.jobs):
            if arrivals:
                # open loop: wait for this job's arrival; a late tenant
                # starts immediately (the arrival queued behind job k-1)
                delay = (start_t[0] + arrivals[k]) - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
            wl.run_once(session, root)
            tn.jobs_done += 1
            if not arrivals and k + 1 < args.jobs:
                time.sleep(think_rng.expovariate(1.0 / args.think_mean))
        session.drain(30.0)
        tn.wall_s = time.perf_counter() - t0
        tn.shed = session.runtime.stats()["admission_dropped"]
    except Exception as exc:  # surface, don't hang the join
        import traceback

        tn.error = f"{type(exc).__name__}: {exc}\n{traceback.format_exc()}"
    finally:
        session.close()


def run_loadgen(args) -> list[dict]:
    kind, rate = parse_arrival(args.arrival)
    mix = [m for m in args.mix.split(",") if m]
    cat = _catalog()

    client = POSClient(n_services=args.services, latency=BENCH_LATENCY,
                       cache_capacity=args.cache_capacity,
                       shared_budget=args.cache_capacity > 0,
                       replication=args.replication)
    if args.scenario == "crash" and args.replication < 2:
        raise SystemExit("--scenario crash needs --replication >= 2 "
                         "(with one replica the crashed data is simply gone)")
    obs = Observability(tracing=True)
    client.store.attach_obs(obs)
    roots: dict[str, int] = {}
    for key in mix:
        wl = cat[key]
        if wl.name not in client.logic_module.registered:
            client.register(wl.build_app())
        roots[key] = wl.populate(client.store)

    # same seeded assignment scheme as the virtual mirror, so a wall row
    # and its virtual twin describe the same tenant population
    rng = random.Random(args.seed)
    assignment = rng.choices(mix, weights=heavy_tailed_weights(len(mix)),
                             k=args.tenants)
    tenants = [_TenantRun(i, f"t{i:03d}", assignment[i])
               for i in range(args.tenants)]

    barrier = threading.Barrier(args.tenants + 1)
    start_t = [0.0]
    threads = []
    for tn in tenants:
        arr_rng = random.Random(
            (args.seed << 16) ^ (tn.idx * 2654435761 & 0xFFFFFFFF))
        arrivals: list[float] = []
        if kind == "poisson":
            t_arr = 0.0
            for _ in range(args.jobs):
                t_arr += arr_rng.expovariate(rate / args.tenants)
                arrivals.append(t_arr)
        th = threading.Thread(
            target=_tenant_worker,
            args=(client, tn, cat[tn.app_key], roots[tn.app_key], args,
                  barrier, start_t, arrivals, arr_rng),
            name=f"loadgen-{tn.label}", daemon=True,
        )
        threads.append(th)
        th.start()

    crash_timer = None
    if args.scenario == "crash":
        # silent crash mid-run: nobody is told, so failovers must come from
        # the demand path tripping over ServiceCrashed (the fast path) or
        # the heartbeat monitor timing the corpse out (the slow path)
        crash_timer = threading.Timer(
            args.crash_after,
            lambda: client.store.crash_service(0, announce=False))
        crash_timer.daemon = True
        crash_timer.start()
    run_t0 = time.perf_counter()
    start_t[0] = run_t0
    barrier.wait(timeout=30.0)
    for th in threads:
        th.join()
    run_wall = time.perf_counter() - run_t0
    if crash_timer is not None:
        crash_timer.cancel()

    failed = [tn for tn in tenants if tn.error]
    if failed:
        raise RuntimeError(
            f"{len(failed)}/{len(tenants)} tenants failed; first: "
            f"{failed[0].label} ({failed[0].app_key}): {failed[0].error}")

    # -- collect: stall histograms, span attribution, fairness ---------------
    evicted: dict[str, int] = {}
    for span in obs.tracer.spans():
        if span.outcome == "evicted" and span.session:
            evicted[span.session] = evicted.get(span.session, 0) + 1

    base = {
        "clock": "wall", "tenants": args.tenants, "arrival": args.arrival,
        "mix": "+".join(mix), "dispatch": args.dispatch, "mode": args.mode,
        "cache_capacity": args.cache_capacity,
        "shared_budget": args.cache_capacity > 0,
        "max_outstanding": args.max_outstanding,
        "fairness_ratio": "", "seed": args.seed,
        "scenario": args.scenario,
    }
    # per-tenant failover attribution: the store charges each failover to
    # the session label whose demand access re-routed (crash legs assert
    # every failover lands on a real tenant, never the empty label)
    failovers_by = dict(client.store.failovers_by_session)
    rows = []
    means = []
    total_stall = 0.0
    total_ops = 0
    for tn in tenants:
        hist = obs.registry.histogram("tenant_stall_s", tenant=tn.label)
        p50, p99, p999 = hist.percentiles((0.5, 0.99, 0.999))
        ops = hist.count
        mean = hist.sum / ops if ops else 0.0
        if ops:
            means.append(mean)
        total_stall += hist.sum
        total_ops += ops
        row = dict(base)
        row.update(
            tenant=tn.label, app=tn.app_key, jobs=tn.jobs_done, ops=ops,
            stall_p50_s=round(p50 or 0.0, 9), stall_p99_s=round(p99 or 0.0, 9),
            stall_p999_s=round(p999 or 0.0, 9), stall_mean_s=round(mean, 9),
            stall_total_s=round(hist.sum, 9),
            evicted_before_use=evicted.get(tn.label, 0),
            admission_shed=tn.shed, wall_s=round(tn.wall_s, 3),
            failovers=failovers_by.get(tn.label, 0),
        )
        rows.append(row)
    fairness = (max(means) / max(min(means), 1e-12)) if means else 0.0
    agg = dict(base)
    agg.update(
        tenant="ALL", app="mix", jobs=sum(tn.jobs_done for tn in tenants),
        ops=total_ops, stall_p50_s="", stall_p99_s="", stall_p999_s="",
        stall_mean_s=round(total_stall / max(1, total_ops), 9),
        stall_total_s=round(total_stall, 9),
        evicted_before_use=sum(evicted.values()),
        admission_shed=sum(tn.shed for tn in tenants),
        fairness_ratio=round(fairness, 4), wall_s=round(run_wall, 3),
        failovers=client.store.metrics.failovers,
    )
    rows.append(agg)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tenants", type=int, default=8,
                    help="concurrent labeled sessions over the shared store")
    ap.add_argument("--jobs", type=int, default=2, help="jobs per tenant")
    ap.add_argument("--arrival", default="closed",
                    help="'closed' (exponential think) or 'poisson:RATE' "
                         "(open, aggregate RATE jobs/s)")
    ap.add_argument("--mix", default=",".join(DEFAULT_MIX),
                    help="comma-separated catalog keys, cheapest-first "
                         "(heavy-tailed 1/rank weights)")
    ap.add_argument("--mode", default="capre",
                    help="predictor mode for every tenant session")
    ap.add_argument("--dispatch", default="batch")
    ap.add_argument("--cache-capacity", type=int, default=256,
                    help="shared line budget across all Data Services "
                         "(0 = unbounded, no budget)")
    ap.add_argument("--max-outstanding", type=int, default=8,
                    help="per-session admission bound (0 = unbounded)")
    ap.add_argument("--admission-threshold", type=float, default=0.5)
    ap.add_argument("--workers", type=int, default=4,
                    help="parallel prefetch workers per session (kept small: "
                         "N tenants each own a pool)")
    ap.add_argument("--services", type=int, default=4)
    ap.add_argument("--replication", type=int, default=1,
                    help="replica count per object (primary + ring "
                         "successors); crash legs need >= 2")
    ap.add_argument("--scenario", default="no-fault",
                    choices=("no-fault", "crash"),
                    help="'crash' silently kills service 0 mid-run "
                         "(--crash-after seconds in) and relies on failover")
    ap.add_argument("--crash-after", type=float, default=0.05,
                    help="seconds after the start barrier before the crash "
                         "leg kills service 0")
    ap.add_argument("--think-mean", type=float, default=5e-3,
                    help="closed-loop mean think time between jobs, seconds")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join("artifacts", "predict"),
                    help="directory for loadgen.csv")
    ap.add_argument("--append", action="store_true",
                    help="append to an existing loadgen.csv (CI matrix legs)")
    ap.add_argument("--no-csv", action="store_true")
    args = ap.parse_args(argv)

    with timer_warm_keeper():
        rows = run_loadgen(args)
    agg = rows[-1]
    print(f"# loadgen tenants={args.tenants} arrival={args.arrival} "
          f"mode={args.mode} dispatch={args.dispatch} wall={agg['wall_s']}s")
    print(f"#   ops={agg['ops']} mean_stall={agg['stall_mean_s']}s "
          f"fairness={agg['fairness_ratio']} "
          f"evicted_before_use={agg['evicted_before_use']} "
          f"shed={agg['admission_shed']}")
    for row in rows[:-1]:
        print(f"{row['tenant']},{row['app']},jobs={row['jobs']},"
              f"ops={row['ops']},p99={row['stall_p99_s']}s,"
              f"evicted={row['evicted_before_use']},shed={row['admission_shed']}")
    if not args.no_csv:
        path = os.path.join(args.out, "loadgen.csv")
        write_loadgen_csv(path, rows, append=args.append)
        print(f"# wrote {path} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
