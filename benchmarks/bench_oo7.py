"""OO7 benchmark — paper Figure 10 (traversals t1 and t2b, small/medium)."""

from __future__ import annotations

from repro.apps.oo7 import build_oo7_app, populate_oo7

from .common import MODES, BenchResult, run_modes


def bench_t1(reps: int = 3, sizes=("small", "medium")) -> list[BenchResult]:
    results = []
    for size in sizes:
        results += run_modes(
            "oo7_t1",
            size,
            build_oo7_app,
            lambda store, size=size: populate_oo7(store, size=size),
            lambda s, root: s.execute(root, "t1"),
            modes=MODES,
            reps=reps,
        )
    return results


def bench_t2b(reps: int = 3) -> list[BenchResult]:
    return run_modes(
        "oo7_t2b",
        "small",
        build_oo7_app,
        lambda store: populate_oo7(store, size="small"),
        lambda s, root: s.execute(root, "t2b"),
        modes=MODES,
        reps=reps,
    )


def run(reps: int = 3) -> list[BenchResult]:
    return bench_t1(reps=reps) + bench_t2b(reps=reps)
