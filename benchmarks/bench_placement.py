"""Placement-policy x failure-scenario matrix over the deterministic
replay path (the placement tentpole's acceptance harness).

For each app the workload trace is recorded ONCE; each placement policy is
then applied to the same store via ``ObjectStore.rebuild_placement`` (the
put log replays under the new policy), and every requested failure
scenario replays on the virtual clock:

  * **no-fault**  — clean run; placement equivalence says every policy
    reaches the same timely_coverage here (the prefetched *sets* are
    identical; only which Data Service serves each oid moves);
  * **straggler** — one Data Service's disk runs ``straggler_scale``x slow;
    replica-aware routing (replication >= 2) steers load off it;
  * **crash**     — one Data Service dies mid-run: its cache is lost and
    in-flight prefetches re-dispatch to surviving replicas.

Per row the CSV reports per-predictor ``timely_coverage``, stall seconds,
``failovers``, and ``batch_dispatches`` — the last being the
cross-service submission count the locality-aware policy is built to
shrink (co-located hint subtrees collapse a prediction's fan-out onto
fewer services).  The run summary prints that reduction explicitly for
bank and oo7.

Usage: PYTHONPATH=src python -m benchmarks.bench_placement \
    [--apps bank,oo7] [--placements round-robin,consistent-hash,locality] \
    [--scenarios no-fault,straggler,crash] [--replication 2] \
    [--modes static-capre,rop] [--out artifacts/predict/placement.csv]
"""

from __future__ import annotations

import argparse
import os
from typing import Optional

from repro.pos.placement import available_placements
from repro.predict.evaluate import (
    _catalog,
    evaluate_workload,
    record_workload,
    replay,
    replay_baseline,
    write_csv,
)


def run_matrix(apps, placements, scenarios, replication: int,
               modes=None) -> list:
    results = []
    catalog = _catalog()
    for app in apps:
        wl = catalog[app]
        recorded = record_workload(wl, runs=2)
        for placement in placements:
            rows = evaluate_workload(
                wl, modes=modes, recorded=recorded,
                dispatch_modes=("batch",),
                placement=placement, replication=replication,
                scenarios=tuple(scenarios),
            )
            results.extend(rows)
    return results


def run_recovery_sweep(app: str, replication: int = 2,
                       mode: str = "static-capre", crash_frac: float = 0.25,
                       revive_fracs=(0.30, 0.40, 0.50, 0.60, 0.80)) -> list:
    """Readmission timing sweep: crash service 0 at ``crash_frac`` of the
    clean run, revive it at each of ``revive_fracs`` — stall-vs-time around
    the readmission.  The later the revive, the more missed writes
    anti-entropy has to resync on readmission (``resync_lines`` grows with
    the revive point), so run this on a mutating traversal
    (``bank_write``): on a read-only app the surviving replica absorbs the
    whole working set before any revive point and every row is identical.
    Returns ``ReplayResult`` rows whose scenario names carry the revive
    fraction (``crash+revive@0.40``)."""
    from repro.pos.client import SessionConfig
    from repro.pos.latency import FailureScenario
    from repro.predict import make_pos_predictor

    wl = _catalog()[app]
    client, _root, traces = record_workload(wl, runs=2)
    train, eval_ = traces[0], traces[-1]
    store = client.store
    store.rebuild_placement("round-robin", replication=replication)
    reg = client.logic_module.registered[wl.name]
    nofault = replay_baseline(eval_, store)
    end_t = nofault.t - nofault.stall_seconds
    results = []
    for frac in revive_fracs:
        sc = FailureScenario(name=f"crash+revive@{frac:.2f}",
                             crash_service=0, crash_at=end_t * crash_frac,
                             revive_at=end_t * frac)
        predictor = make_pos_predictor(mode, config=SessionConfig())
        predictor.warm(train.accesses)
        results.append(replay(eval_, predictor, store, reg, scenario=sc))
    return results


def summarize_recovery(results) -> list[str]:
    lines = []
    header = (f"{'scenario':<18} {'stall_s':>8} {'failovers':>9} "
              f"{'readmit':>7} {'resync':>6} {'p99_s':>8}")
    lines.append(header)
    lines.append("-" * len(header))
    for r in results:
        o = r.overhead
        lines.append(
            f"{r.scenario:<18} {r.stall_seconds:>8.4f} {r.failovers:>9d} "
            f"{o['readmissions']:>7d} {o['resync_lines']:>6d} "
            f"{r.stall_p99_s:>8.4f}"
        )
    return lines


def _dispatch_total(results, app: str, placement: str) -> Optional[int]:
    """Summed cross-service batch submissions for one (app, placement) in
    the clean regime (faults add failover re-dispatches, which would
    conflate recovery traffic with placement quality)."""
    cells = [r.batch_dispatches for r in results
             if r.app == app and r.placement == placement
             and r.scenario == "no-fault"]
    return sum(cells) if cells else None


def summarize(results, apps, placements) -> list[str]:
    lines = []
    header = (f"{'app':<10} {'placement':<16} {'scenario':<10} "
              f"{'predictor':<14} {'t.cov':>6} {'stall_s':>8} "
              f"{'failovers':>9} {'batches':>8}")
    lines.append(header)
    lines.append("-" * len(header))
    for r in results:
        lines.append(
            f"{r.app:<10} {r.placement:<16} {r.scenario:<10} "
            f"{r.predictor:<14} {r.timely_coverage:>6.3f} "
            f"{r.stall_seconds:>8.4f} {r.failovers:>9d} "
            f"{r.batch_dispatches:>8d}"
        )
    if "round-robin" in placements and "locality" in placements:
        for app in apps:
            rr = _dispatch_total(results, app, "round-robin")
            loc = _dispatch_total(results, app, "locality")
            if not rr or loc is None:
                continue
            lines.append(
                f"# {app}: locality batch submissions {loc} vs "
                f"round-robin {rr} ({100.0 * (rr - loc) / rr:+.1f}% fewer)"
            )
    return lines


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--apps", default="bank,oo7")
    ap.add_argument("--placements", default=",".join(available_placements()))
    ap.add_argument("--scenarios", default="no-fault,straggler,crash")
    ap.add_argument("--replication", type=int, default=2,
                    help="replica count (>= 2 lets faults fail over)")
    ap.add_argument("--modes", default="static-capre,rop",
                    help="predictors to replay (empty = full registry)")
    ap.add_argument("--recovery-sweep", action="store_true",
                    help="also sweep crash-at-T / revive-at-T+D readmission "
                         "timings on the first app (stall vs revive point)")
    ap.add_argument("--out", default=os.path.join("artifacts", "predict",
                                                  "placement.csv"))
    ap.add_argument("--no-csv", action="store_true")
    args = ap.parse_args(argv)

    apps = [a for a in args.apps.split(",") if a]
    placements = [p for p in args.placements.split(",") if p]
    scenarios = [s for s in args.scenarios.split(",") if s]
    modes = tuple(m for m in args.modes.split(",") if m) or None

    results = run_matrix(apps, placements, scenarios, args.replication,
                         modes=modes)
    for line in summarize(results, apps, placements):
        print(line)
    if args.recovery_sweep:
        # Prefer a mutating traversal: revive timing only moves the numbers
        # when the dead replica misses writes (resync on readmission).
        sweep_app = ("bank_write" if "bank_write" in _catalog() else apps[0])
        recovery = run_recovery_sweep(sweep_app, replication=args.replication,
                                      mode=(modes or ("static-capre",))[0])
        print(f"# recovery sweep ({sweep_app}, crash@0.25, revive swept):")
        for line in summarize_recovery(recovery):
            print(line)
        results.extend(recovery)
    if not args.no_csv:
        path = write_csv(results, args.out)
        print(f"# wrote {path} ({len(results)} rows)")
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
