"""Compare two dry-run artifacts (baseline vs hillclimb variant): the
hypothesis->change->measure loop's measurement step.

Usage: PYTHONPATH=src python -m benchmarks.compare \
    artifacts/dryrun/yi_34b__train_4k__single.json \
    artifacts/dryrun/yi_34b__train_4k__single__sp.json
"""

from __future__ import annotations

import json
import sys

from .roofline import roofline_terms


def compare(a_path: str, b_path: str) -> dict:
    a = json.loads(open(a_path).read())
    b = json.loads(open(b_path).read())
    ra, rb = roofline_terms(a), roofline_terms(b)
    out = {"baseline": a_path, "variant": b_path, "overrides": b.get("overrides", {})}
    for key in ("t_compute_s", "t_memory_s", "t_collective_s", "useful_ratio", "mfu_bound"):
        va, vb = ra[key], rb[key]
        delta = (vb - va) / va * 100 if va else float("inf")
        out[key] = {"baseline": va, "variant": vb, "delta_pct": delta}
    out["dominant"] = {"baseline": ra["dominant"], "variant": rb["dominant"]}
    mem = ("mem_temp_size_in_bytes",)
    for k in mem:
        if k in a and k in b:
            out[k] = {"baseline": a[k], "variant": b[k], "delta_pct": (b[k] - a[k]) / a[k] * 100}
    return out


def main() -> None:
    res = compare(sys.argv[1], sys.argv[2])
    print(f"baseline: {res['baseline']}")
    print(f"variant:  {res['variant']}  overrides={res['overrides']}")
    for k in ("t_compute_s", "t_memory_s", "t_collective_s", "useful_ratio", "mfu_bound",
              "mem_temp_size_in_bytes"):
        if k not in res:
            continue
        v = res[k]
        unit = " s" if k.startswith("t_") else ""
        print(f"  {k:24s} {v['baseline']:.6g}{unit} -> {v['variant']:.6g}{unit}  ({v['delta_pct']:+.1f}%)")
    print(f"  dominant: {res['dominant']['baseline']} -> {res['dominant']['variant']}")


if __name__ == "__main__":
    main()
