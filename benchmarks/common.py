"""Shared machinery for the paper-table benchmarks (section 7).

Every benchmark runs a traversal under a set of prefetching modes
(no-prefetch / ROP at several fetch depths / CAPre), repeats it ``reps``
times on cold caches, and reports mean wall-clock execution time of the
application thread (prefetch threads keep running in the background, exactly
like the paper's injected executor)."""

from __future__ import annotations

import contextlib
import statistics
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.pos.client import POSClient
from repro.pos.latency import LatencyModel

# Latency model used for all paper benchmarks (see pos/latency.py for the
# rationale; the paper's absolute numbers depend on their cluster, ours on
# these constants — the *relative* behavior is what we reproduce).
# One disk arm per Data Service (the paper's nodes have a single 5400rpm
# HDD): reads and writes on one DS serialize; parallelism comes from the
# four Data Services — which is exactly what makes CAPre's distributed
# parallel prefetch profitable and ROP's useless reads costly.
BENCH_LATENCY = LatencyModel(
    disk_load=300e-6, remote_hop=120e-6, write_back=900e-6, think=100e-6, parallel_per_ds=1
)

@contextlib.contextmanager
def timer_warm_keeper():
    """Keep one core busy (GIL-yielding spin) for the duration of a
    benchmark so timed-sleep wakeups are uniformly cheap across modes.

    On virtualized / idle-capable hosts, waking a ``time.sleep`` from an
    *idle* CPU costs ~0.5-1 ms extra versus preempting a busy one.  A
    dispatch mode that schedules thousands of tiny background tasks keeps
    the CPUs accidentally warm and gets fast wakeups; an efficient mode
    that leaves the CPUs idle gets punished on every application think
    sleep — measured on OO7, this idle-exit tax was larger than the entire
    between-mode difference.  Spinning one yielding thread makes sleep
    latency a constant, so mode deltas reflect the code under test."""
    stop = threading.Event()

    def spin() -> None:
        while not stop.is_set():
            for _ in range(1000):
                pass
            time.sleep(0)  # release the GIL every burst

    th = threading.Thread(target=spin, name="bench-warm", daemon=True)
    th.start()
    try:
        yield
    finally:
        stop.set()
        th.join(timeout=1.0)


MODES = (
    ("none", None, 0),
    ("rop_d1", "rop", 1),
    ("rop_d2", "rop", 2),
    ("rop_d5", "rop", 5),
    ("capre", "capre", 0),
)
MODES_SHORT = (("none", None, 0), ("rop_d2", "rop", 2), ("capre", "capre", 0))


@dataclass
class BenchResult:
    benchmark: str
    config: str
    mode: str
    mean_s: float
    stdev_s: float
    reps: int
    metrics: dict

    @property
    def improvement_vs(self) -> Optional[float]:
        return None

    def csv(self, baseline_s: Optional[float] = None) -> str:
        us = self.mean_s * 1e6
        derived = ""
        if baseline_s:
            derived = f"improvement={100.0 * (1 - self.mean_s / baseline_s):.1f}%"
        return f"{self.benchmark}/{self.config}/{self.mode},{us:.0f},{derived}"


def run_modes(
    benchmark: str,
    config: str,
    build_app: Callable,
    populate: Callable[[object], object],
    run_once: Callable[[object, object], None],
    modes=MODES,
    reps: int = 3,
    n_services: int = 4,
    parallel_workers: int = 16,
) -> list[BenchResult]:
    """Build one store per mode (placement identical: same seeds), run
    ``reps`` cold-cache repetitions, return one result per mode."""
    out: list[BenchResult] = []
    with timer_warm_keeper():
        for mode_name, mode, depth in modes:
            client = POSClient(n_services=n_services, latency=BENCH_LATENCY)
            client.register(build_app())
            root = populate(client.store)
            times = []
            metrics = {}
            for _ in range(reps):
                client.store.reset_runtime_state()
                with client.session(
                    client.logic_module.registered and list(client.logic_module.registered)[0],
                    mode=mode,
                    rop_depth=depth,
                    parallel_workers=parallel_workers,
                ) as s:
                    t0 = time.perf_counter()
                    run_once(s, root)
                    times.append(time.perf_counter() - t0)
                    if not s.drain(30.0):
                        import warnings

                        warnings.warn(
                            f"{benchmark}/{config}/{mode_name}: prefetch drain "
                            "timed out; metrics for this rep are incomplete",
                            RuntimeWarning,
                        )
                    metrics = client.store.snapshot_metrics()
                    metrics.update(client.store.prefetch_accuracy())
            out.append(
                BenchResult(
                    benchmark=benchmark,
                    config=config,
                    mode=mode_name,
                    mean_s=statistics.mean(times),
                    stdev_s=statistics.stdev(times) if len(times) > 1 else 0.0,
                    reps=reps,
                    metrics=metrics,
                )
            )
    return out


def print_results(results: list[BenchResult]) -> None:
    by_cfg: dict[tuple[str, str], float] = {}
    for r in results:
        if r.mode == "none":
            by_cfg[(r.benchmark, r.config)] = r.mean_s
    for r in results:
        base = by_cfg.get((r.benchmark, r.config))
        print(r.csv(baseline_s=base if r.mode != "none" else None))
