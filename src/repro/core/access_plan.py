"""CAPre adapted to JAX: static access analysis over jaxprs.

This is Algorithm 1 transposed onto the TPU stack (DESIGN.md section 2).
The "application" is a jitted step function; the "persistent objects" are
the parameter leaves; and the jaxpr — known entirely at compile time, like
the paper's Wala IR — tells us exactly which parameters each part of the
step touches:

  paper                        | here
  -----------------------------+------------------------------------------
  getfield navigation          | a jaxpr equation consuming a param leaf
  collection + loop iteration  | lax.scan over a stacked-layers param (xs)
  invokemethod augmentation    | recursion into pjit/remat/custom sub-jaxprs
  branch-dependent navigation  | params used under some lax.cond branches
  prefetching hints PH_m       | PrefetchPlan records ordered by first use

The plan drives the weight-streaming runtime (repro.runtime.prefetch): like
the paper's generated prefetch methods it is derived *before* execution and
adds zero runtime monitoring.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np
from jax._src.core import Literal as _Literal


def _lookup(env: dict, v):
    if isinstance(v, _Literal):
        return None
    return env.get(v)


@dataclass
class AccessRecord:
    path: str
    first_use: int  # program-order clock of the first consuming equation
    nbytes: int
    shape: tuple
    collection: bool = False  # scanned-over stacked array (CAPre collection)
    branch_dependent: bool = False  # used under a lax.cond branch (section 4.4)
    uses: int = 1

    def __repr__(self) -> str:
        tags = []
        if self.collection:
            tags.append("[]")
        if self.branch_dependent:
            tags.append("bd")
        return f"<{self.path}@{self.first_use} {self.nbytes}B {' '.join(tags)}>"


@dataclass
class PrefetchPlan:
    records: list[AccessRecord]

    @property
    def total_bytes(self) -> int:
        return sum(r.nbytes for r in self.records)

    def ordered(self) -> list[AccessRecord]:
        return sorted(self.records, key=lambda r: r.first_use)

    def collections(self) -> list[AccessRecord]:
        return [r for r in self.records if r.collection]

    def hints(self) -> list[str]:
        """String hints, CAPre-style."""
        return [
            r.path + ("[]" if r.collection else "") for r in self.ordered()
        ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


def build_access_plan(fn, params, *args, **kwargs) -> PrefetchPlan:
    """Trace ``fn(params, *args)`` and derive the parameter access plan.

    ``params`` may be concrete arrays or ShapeDtypeStructs (no allocation
    needed — same property as the paper's compile-time analysis)."""
    closed = jax.make_jaxpr(lambda p, *a: fn(p, *a, **kwargs))(params, *args)
    jaxpr = closed.jaxpr

    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    n_params = len(leaves)
    # the first n_params flattened invars belong to `params`
    var_info: dict = {}
    for (path, leaf), var in zip(leaves, jaxpr.invars[:n_params]):
        var_info[var] = _path_str(path)

    records: dict[str, AccessRecord] = {}
    clock = [0]
    use_log: list[set] = []  # per-branch used-path sets (for cond promotion)

    def record_use(pathname, aval, *, collection=False, branch=False):
        for s in use_log:
            s.add(pathname)
        r = records.get(pathname)
        nbytes = int(np.prod(aval.shape)) * aval.dtype.itemsize
        if r is None:
            records[pathname] = AccessRecord(
                path=pathname,
                first_use=clock[0],
                nbytes=nbytes,
                shape=tuple(aval.shape),
                collection=collection,
                branch_dependent=branch,
            )
        else:
            r.uses += 1
            r.collection |= collection
            # a use on an unconditional path clears branch-dependence
            # (the union-of-branches promotion of section 4.4)
            if not branch:
                r.branch_dependent = False

    def walk(jx, env: dict, in_branch: bool):
        """env maps jx's vars -> param path names."""
        for eqn in jx.eqns:
            clock[0] += 1
            prim = eqn.primitive.name
            sub = _sub_jaxpr(eqn)
            if prim == "scan" and sub is not None:
                n_consts = eqn.params["num_consts"]
                n_carry = eqn.params["num_carry"]
                body = sub
                body_env = {}
                for i, outer in enumerate(eqn.invars):
                    name = _lookup(env, outer)
                    if name is None:
                        continue
                    inner = body.invars[i]
                    if i >= n_consts + n_carry:
                        # scanned xs: the stacked-layers collection —
                        # every element will be accessed (CAPre collection)
                        record_use(name, outer.aval, collection=True, branch=in_branch)
                    body_env[inner] = name
                walk(body, body_env, in_branch)
            elif prim == "cond":
                branches = eqn.params["branches"]
                branch_used: list[set] = []
                for br in branches:
                    br_env = {}
                    # cond invars: (index, *operands)
                    for inner, outer in zip(br.jaxpr.invars, eqn.invars[1:]):
                        if _lookup(env, outer) is not None:
                            br_env[inner] = env[outer]
                    use_log.append(set())
                    walk(br.jaxpr, br_env, True)
                    branch_used.append(use_log.pop())
                # section 4.4 promotion: a param accessed in EVERY branch is
                # not branch-dependent ("the accessed objects are the same
                # although the methods executed may differ")
                in_all = set.intersection(*branch_used) if branch_used else set()
                for pathname in in_all:
                    if pathname in records and not in_branch:
                        records[pathname].branch_dependent = False
            elif sub is not None:
                sub_env = {}
                for inner, outer in zip(sub.invars, eqn.invars):
                    if _lookup(env, outer) is not None:
                        sub_env[inner] = env[outer]
                walk(sub, sub_env, in_branch)
            else:
                for v in eqn.invars:
                    name = _lookup(env, v)
                    if name is not None:
                        record_use(name, v.aval, branch=in_branch)

    env0 = dict(var_info)
    walk(jaxpr, env0, False)
    return PrefetchPlan(records=list(records.values()))


def _sub_jaxpr(eqn):
    p = eqn.primitive.name
    params = eqn.params
    if p == "scan":
        return params["jaxpr"].jaxpr
    if p in ("pjit", "closed_call", "remat2", "remat", "checkpoint", "custom_vjp_call_jaxpr"):
        j = params.get("jaxpr") or params.get("call_jaxpr") or params.get("fun_jaxpr")
        return getattr(j, "jaxpr", j) if j is not None else None
    if p in ("custom_jvp_call", "custom_vjp_call"):
        j = params.get("call_jaxpr") or params.get("fun_jaxpr")
        return getattr(j, "jaxpr", j) if j is not None else None
    if p == "shard_map":
        j = params.get("jaxpr")
        return getattr(j, "jaxpr", j) if j is not None else None
    if p == "while":
        return params["body_jaxpr"].jaxpr
    return None


def rop_plan(params, depth_groups: int) -> PrefetchPlan:
    """The ROP baseline on the tensor store: schema-only — prefetch the
    first ``depth_groups`` top-level parameter groups in tree order,
    never 'collections' (it cannot know a scan consumes all layers).
    Mirrors the paper's depth-limited referenced-object expansion."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    groups: dict[str, list] = {}
    for path, leaf in leaves:
        top = _path_str(path).split(".")[0]
        groups.setdefault(top, []).append((path, leaf))
    records = []
    for gi, (gname, members) in enumerate(groups.items()):
        if gi >= depth_groups:
            break
        for path, leaf in members:
            records.append(
                AccessRecord(
                    path=_path_str(path),
                    first_use=gi,
                    nbytes=int(np.prod(leaf.shape)) * leaf.dtype.itemsize,
                    shape=tuple(leaf.shape),
                )
            )
    return PrefetchPlan(records=records)
