"""Wala-like Intermediate Representation (paper section 5.1.1).

Each IR instruction carries the five parts described in the paper:

  * ``ii``        — the instruction's index inside the IR,
  * ``itype``     — the instruction type (getfield, invokemethod, ...),
  * ``params``    — instruction parameters (accessed field, invoked method...),
  * ``def_var``   — the variable ID defined by the instruction (or None),
  * ``used_vars`` — previously-defined variable IDs used by the instruction,

plus the AST facts Algorithm 1 queries through getASTNode /
hasConditionalParent / hasLoopParent, which we materialize directly on the
instruction:

  * ``branch_path`` — enclosing conditional branches as a tuple of
                      ``(cond_id, branch_idx, n_branches)`` triples,
  * ``loop_path``   — enclosing loop statement IDs (innermost last).

Variable IDs follow Wala's convention loosely: ``v1`` is the self reference
``this``, ``v2..`` are the method parameters, then temporaries.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

# Instruction types (paper Table 3 + the control instructions of Listing 2).
GETFIELD = "getfield"
PUTFIELD = "putfield"
ARRAYLOAD = "arrayload"
INVOKE = "invokemethod"
ITER_INIT = "iterator"  # invokemethod java/util/ArrayList.iterator()
ITER_HASNEXT = "hasnext"  # invokemethod java/util/Iterator.hasNext()
ITER_NEXT = "next"  # invokemethod java/util/Iterator.next()
RETURN = "return"
BREAK = "break"
CONTINUE = "continue"
CONDBRANCH = "conditionalbranch"
GOTO = "goto"
CONST = "const"
COMPUTE = "compute"
NEW = "new"

BRANCHING = (RETURN, BREAK, CONTINUE)


@dataclass
class Instr:
    ii: int
    itype: str
    params: dict[str, Any] = field(default_factory=dict)
    def_var: Optional[str] = None
    used_vars: tuple[str, ...] = ()
    branch_path: tuple[tuple[int, int, int], ...] = ()
    loop_path: tuple[int, ...] = ()

    # --- the AST queries used by Algorithm 1 -----------------------------
    @property
    def has_conditional_parent(self) -> bool:
        return len(self.branch_path) > 0

    @property
    def has_loop_parent(self) -> bool:
        return len(self.loop_path) > 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        use = ", ".join(self.used_vars)
        d = f"{self.def_var} = " if self.def_var else ""
        p = ", ".join(f"{k}={v}" for k, v in self.params.items() if k != "fn")
        return f"II{self.ii}: {d}{self.itype} <{p}> : {use}"


@dataclass
class MethodIR:
    owner: str
    name: str
    # params as (var_id, name, declared type or None); params[0] is `this`
    params: tuple[tuple[str, str, Optional[str]], ...]
    instrs: list[Instr]

    @property
    def key(self) -> str:
        return f"{self.owner}.{self.name}"

    @property
    def this_var(self) -> str:
        return self.params[0][0]

    def param_var(self, index: int) -> str:
        return self.params[index][0]

    def dump(self) -> str:
        head = f"IR of {self.key}({', '.join(p[1] for p in self.params[1:])})"
        return "\n".join([head] + ["  " + repr(i) for i in self.instrs])
