"""Object-oriented application language (AST) for CAPre.

CAPre (Touma et al., FGCS 2019) analyzes Java applications through IBM Wala:
source -> AST + IR -> augmented method type graphs -> prefetching hints.

We reproduce the same pipeline with a small object-oriented AST that plays the
role of the Java source / Wala AST.  A single definition of an application is
used by BOTH:

  * ``core.lower``      -- lowers the AST to a Wala-like IR (``core.ir``) that
                           Algorithm 1 (``core.type_graph``) consumes, and
  * ``pos.interp``      -- executes the AST against the distributed persistent
                           object store, with latency accounting.

This guarantees the static analysis and the executed program can never drift
apart (the paper has the same property: Wala analyzes the bytecode that runs).

The language supports exactly the constructs the paper's analysis handles:
field navigations (single / collection associations), primitive field access,
method invocation (with dynamic dispatch), conditionals, loops with
break/continue/return, and opaque primitive computation.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

SINGLE = "single"
COLLECTION = "collection"


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FieldSpec:
    """A member field of a class.

    ``target`` is a class name for persistent associations and ``None`` for
    primitive fields.  ``card`` distinguishes single vs collection
    associations (paper section 4.2.1).
    """

    name: str
    target: Optional[str] = None
    card: str = SINGLE

    @property
    def is_persistent(self) -> bool:
        return self.target is not None


@dataclass
class ClassDef:
    name: str
    fields: dict[str, FieldSpec] = field(default_factory=dict)
    methods: dict[str, "MethodDef"] = field(default_factory=dict)
    supertype: Optional[str] = None

    def add_method(self, m: "MethodDef") -> "ClassDef":
        m.owner = self.name
        self.methods[m.name] = m
        return self


def fields_of(*specs: FieldSpec) -> dict[str, FieldSpec]:
    return {s.name: s for s in specs}


# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


class Expr:
    pass


@dataclass
class This(Expr):
    pass


@dataclass
class Var(Expr):
    name: str


@dataclass
class Const(Expr):
    value: Any


@dataclass
class Get(Expr):
    """Field access ``obj.field``.

    If the field is a persistent association this is an association
    navigation; if it is primitive it is ignored by the analysis (paper:
    "instructions that involve fields of primitive types ... are not part of
    the graph").
    """

    obj: Expr
    field: str


@dataclass
class Call(Expr):
    """Method invocation ``obj.method(args...)`` with dynamic dispatch."""

    obj: Expr
    method: str
    args: tuple[Expr, ...] = ()


@dataclass
class Compute(Expr):
    """Opaque primitive computation.

    ``fn`` runs over the evaluated argument values at interpretation time.
    The static analysis treats it like arithmetic over primitives: it defines
    a non-persistent value and triggers no navigations.  ``label`` is only for
    debugging.
    """

    fn: Callable[..., Any]
    args: tuple[Expr, ...] = ()
    label: str = "compute"


@dataclass
class New(Expr):
    """Allocate a fresh (volatile) object of a persistent class.

    Used by update traversals; allocation itself is not a navigation.
    """

    cls: str
    inits: dict[str, Expr] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


class Stmt:
    pass


@dataclass
class Let(Stmt):
    var: str
    expr: Expr


@dataclass
class SetField(Stmt):
    """``obj.field = value`` — a putfield.  Primitive stores mark the object
    dirty (write-back cost in the POS); reference stores rewire associations.
    putfield is not an association navigation (Table 3 does not include it),
    but evaluating ``obj`` may navigate.
    """

    obj: Expr
    field: str
    value: Expr


@dataclass
class ExprStmt(Stmt):
    expr: Expr


@dataclass
class If(Stmt):
    cond: Expr
    then: list[Stmt]
    els: list[Stmt] = field(default_factory=list)


@dataclass
class While(Stmt):
    cond: Expr
    body: list[Stmt]


@dataclass
class ForEach(Stmt):
    """Iterate a persistent collection association (``for (T x : obj.f)``).

    Lowered to the iterator()/hasNext()/next() IR pattern of the paper's
    Listing 2; ``next()`` inside the loop is the collection association
    navigation (Table 3).
    """

    var: str
    obj: Expr
    field: str
    body: list[Stmt] = dataclasses.field(default_factory=list)


@dataclass
class ForEachLocal(Stmt):
    """Iterate a *local* (non-persistent) Python iterable — e.g. a worklist.

    This is how data-dependent traversals (Bellman-Ford's queue) appear:
    the analysis sees a loop but no collection association navigation.
    """

    var: str
    iterable: Expr
    body: list[Stmt] = dataclasses.field(default_factory=list)


@dataclass
class Return(Stmt):
    expr: Optional[Expr] = None


@dataclass
class Break(Stmt):
    pass


@dataclass
class Continue(Stmt):
    pass


# ---------------------------------------------------------------------------
# Methods / applications
# ---------------------------------------------------------------------------


@dataclass
class MethodDef:
    name: str
    params: tuple[tuple[str, Optional[str]], ...] = ()
    body: list[Stmt] = field(default_factory=list)
    owner: str = ""  # set by ClassDef.add_method
    ret_type: Optional[str] = None

    @property
    def key(self) -> str:
        return f"{self.owner}.{self.name}"


@dataclass
class Application:
    name: str
    classes: dict[str, ClassDef]

    def method(self, owner: str, name: str) -> MethodDef:
        return self.classes[owner].methods[name]

    def subtypes(self, cls: str) -> list[str]:
        out = []
        for c in self.classes.values():
            t = c.supertype
            while t is not None:
                if t == cls:
                    out.append(c.name)
                    break
                t = self.classes[t].supertype if t in self.classes else None
        return out

    def is_overridden(self, owner: str, method: str) -> bool:
        """Dynamic-binding check of section 4.4: does any subtype of ``owner``
        override ``method``?  If so CAPre must not inline its type graph."""
        for sub in self.subtypes(owner):
            if method in self.classes[sub].methods:
                return True
        return False

    def resolve_method(self, runtime_cls: str, method: str) -> MethodDef:
        """Dynamic dispatch: walk the supertype chain from the runtime class."""
        t: Optional[str] = runtime_cls
        while t is not None:
            c = self.classes[t]
            if method in c.methods:
                return c.methods[method]
            t = c.supertype
        raise AttributeError(f"no method {method} on {runtime_cls}")

    def field_spec(self, cls: str, fname: str) -> FieldSpec:
        t: Optional[str] = cls
        while t is not None:
            c = self.classes[t]
            if fname in c.fields:
                return c.fields[fname]
            t = c.supertype
        raise AttributeError(f"no field {fname} on {cls}")

    def all_methods(self) -> list[MethodDef]:
        return [m for c in self.classes.values() for m in c.methods.values()]

    def type_graph(self) -> dict[tuple[str, str], tuple[str, str]]:
        """The application type graph G_T = (T, A) of section 4.2.1, as the
        association function A: (type, field) -> (target type, cardinality)."""
        assoc: dict[tuple[str, str], tuple[str, str]] = {}
        for c in self.classes.values():
            for f in c.fields.values():
                if f.is_persistent:
                    assoc[(c.name, f.name)] = (f.target, f.card)
        return assoc
