"""Referenced-Objects Predictor (ROP) — the schema-based baseline.

Paper section 1/2: "each time an object is accessed, all the objects
referenced from it are likely to be accessed as well", expanded to a
configurable *fetch depth*.  Two properties the paper leans on:

  * ROP follows **single** associations only — "ROP approaches do not
    prefetch collections because the probability of bringing many unnecessary
    objects is very high";
  * ROP is schema-driven: the same expansion regardless of which method runs,
    which is exactly what makes it both cheap and rigid.
"""

from __future__ import annotations

from . import lang
from .hints import Hint, Steps


def rop_hints(app: lang.Application, type_name: str, depth: int) -> tuple[Hint, ...]:
    """Depth-limited expansion of single associations from ``type_name``
    over the application type graph G_T."""
    assoc = app.type_graph()
    out: list[Steps] = []

    def expand(t: str, steps: Steps, d: int, seen: tuple[str, ...]) -> None:
        if d == 0:
            return
        extended = False
        for (owner, fld), (target, card) in sorted(assoc.items()):
            if owner != t or card != lang.SINGLE:
                continue
            if target in seen:  # schema cycles: stop, ROP re-triggers at runtime
                continue
            extended = True
            nxt = steps + ((fld, lang.SINGLE),)
            out.append(nxt)
            expand(target, nxt, d - 1, seen + (target,))

        _ = extended

    expand(type_name, (), depth, (type_name,))
    # keep maximal paths only (loading a.b loads a on the way)
    maximal = [p for p in out if not any(q != p and q[: len(p)] == p for q in out)]
    return tuple(Hint(p) for p in sorted(maximal, key=str))


def rop_referenced_fields(app: lang.Application, type_name: str) -> list[tuple[str, str]]:
    """Direct single associations of a type: (field, target) — what ROP
    eagerly schedules each time an object of this type is loaded."""
    assoc = app.type_graph()
    return [
        (fld, target)
        for (owner, fld), (target, card) in sorted(assoc.items())
        if owner == type_name and card == lang.SINGLE
    ]
