"""Source-code generation & injection component (paper section 5.2).

For every analyzed method CAPre generates a helper prefetching method that
loads the objects predicted by its hints, and injects a scheduling of that
helper at the beginning of the method.  Here the "generated source" is a
closure over the hint tree; the "injection" is performed by the interpreter,
which schedules the closure on the background executor on method entry —
exactly the behavior of the injected ``prefetchingExecutor.submit`` of
Listing 5.

Hints sharing a prefix are merged into a tree so, like the generated code of
Listing 4, a collection is iterated once and every per-element navigation
happens inside the same parallel fan-out.  The static-optimizer annotations
(core.opt) ride the tree nodes: ``rfo`` nodes are loaded read-for-ownership
(dirty-allocated ahead of their known update site), ``prefix_bound`` nodes
expand only a bounded prefix of their collection, and ``priority`` orders
sibling expansion most-valuable-first.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core import lang
from repro.core.hints import Hint


@dataclass
class _HintTree:
    fld: Optional[str] = None
    card: str = lang.SINGLE
    children: dict[str, "_HintTree"] = field(default_factory=dict)
    # static-optimizer annotations (core.opt), merged across the hints that
    # traverse this node:
    rfo: bool = False  # the object reached by this step is a known update site
    prefix_bound: Optional[int] = None  # partial traversal: expand first N only
    priority: float = 0.0  # max dispatch priority of the hints through here

    def ordered_children(self) -> list["_HintTree"]:
        """Children by descending priority (stable on field name): cheap,
        soon-demanded subtrees dispatch before expensive floods."""
        return sorted(self.children.values(),
                      key=lambda c: (-c.priority, c.fld or ""))


def build_hint_tree(hints: tuple[Hint, ...]) -> _HintTree:
    root = _HintTree()
    visited: set[int] = set()
    for h in hints:
        node = root
        for i, (fld, card) in enumerate(h.steps):
            nxt = node.children.get(fld)
            if nxt is None:
                nxt = _HintTree(fld=fld, card=card)
                node.children[fld] = nxt
            nxt.rfo = nxt.rfo or (i in h.rfo_depths)
            nxt.priority = max(nxt.priority, h.priority)
            # a node stays bounded only while EVERY hint traversing it is
            # truncated there — one full-traversal hint through the same
            # collection makes the merged expansion unbounded again
            bound = h.prefix_bound if h.trunc_step == i else None
            if id(nxt) not in visited:
                nxt.prefix_bound = bound
            elif bound is None or nxt.prefix_bound is None:
                nxt.prefix_bound = None
            else:
                nxt.prefix_bound = max(nxt.prefix_bound, bound)
            visited.add(id(nxt))
            node = nxt
    return root


def tree_rfo_nodes(tree: _HintTree) -> int:
    """Number of RFO-marked nodes in a hint tree (diagnostics/lint)."""
    n = (1 if tree.rfo else 0)
    for c in tree.children.values():
        n += tree_rfo_nodes(c)
    return n


def generate_prefetch_method(hints: tuple[Hint, ...]):
    """Returns ``prefetch(store, runtime, root_oid)`` — the analogue of the
    generated ``<Class>_prefetch.<method>_prefetch(rootObject)``.

    Single associations chain sequentially (``load(a).load(b)``); collection
    associations fan their elements out on the runtime's parallel pool
    (``parallelStream().forEach``), each element continuing its own subtree.
    RFO nodes dirty-allocate their line; truncated collections fan out only
    their static prefix.
    """
    tree = build_hint_tree(hints)
    if not tree.children:
        return None

    def prefetch(store, runtime, root_oid: int) -> None:
        def visit(oid: int, node: _HintTree) -> None:
            rec = store.prefetch_access(oid, rfo=node.rfo)
            if rec is None:
                return
            for child in node.ordered_children():
                ref = rec.fields.get(child.fld)
                if ref is None:
                    continue
                if child.card == lang.COLLECTION:
                    elems = list(ref)
                    if child.prefix_bound is not None:
                        elems = elems[: child.prefix_bound]
                    runtime.fan_out(lambda e, c=child: visit(e, c), elems)
                else:
                    visit(ref, child)

        visit(root_oid, tree)

    return prefetch


def generate_all(report) -> dict[str, object]:
    """Generated prefetch methods for every analyzed method with non-empty
    (deduplicated) hints."""
    out = {}
    for key, hints in report.hints.items():
        fn = generate_prefetch_method(hints)
        if fn is not None:
            out[key] = fn
    return out
