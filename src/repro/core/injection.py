"""Source-code generation & injection component (paper section 5.2).

For every analyzed method CAPre generates a helper prefetching method that
loads the objects predicted by its hints, and injects a scheduling of that
helper at the beginning of the method.  Here the "generated source" is a
closure over the hint tree; the "injection" is performed by the interpreter,
which schedules the closure on the background executor on method entry —
exactly the behavior of the injected ``prefetchingExecutor.submit`` of
Listing 5.

Hints sharing a prefix are merged into a tree so, like the generated code of
Listing 4, a collection is iterated once and every per-element navigation
happens inside the same parallel fan-out.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core import lang
from repro.core.hints import Hint


@dataclass
class _HintTree:
    fld: Optional[str] = None
    card: str = lang.SINGLE
    children: dict[str, "_HintTree"] = field(default_factory=dict)


def build_hint_tree(hints: tuple[Hint, ...]) -> _HintTree:
    root = _HintTree()
    for h in hints:
        node = root
        for fld, card in h.steps:
            nxt = node.children.get(fld)
            if nxt is None:
                nxt = _HintTree(fld=fld, card=card)
                node.children[fld] = nxt
            node = nxt
    return root


def generate_prefetch_method(hints: tuple[Hint, ...]):
    """Returns ``prefetch(store, runtime, root_oid)`` — the analogue of the
    generated ``<Class>_prefetch.<method>_prefetch(rootObject)``.

    Single associations chain sequentially (``load(a).load(b)``); collection
    associations fan their elements out on the runtime's parallel pool
    (``parallelStream().forEach``), each element continuing its own subtree.
    """
    tree = build_hint_tree(hints)
    if not tree.children:
        return None

    def prefetch(store, runtime, root_oid: int) -> None:
        def visit(oid: int, node: _HintTree) -> None:
            rec = store.prefetch_access(oid)
            for child in node.children.values():
                ref = rec.fields.get(child.fld)
                if ref is None:
                    continue
                if child.card == lang.COLLECTION:
                    runtime.fan_out(lambda e, c=child: visit(e, c), list(ref))
                else:
                    visit(ref, child)

        visit(root_oid, tree)

    return prefetch


def generate_all(report) -> dict[str, object]:
    """Generated prefetch methods for every analyzed method with non-empty
    (deduplicated) hints."""
    out = {}
    for key, hints in report.hints.items():
        fn = generate_prefetch_method(hints)
        if fn is not None:
            out[key] = fn
    return out
