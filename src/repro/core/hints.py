"""Prefetching-hint generation (paper sections 4.3 and 5.1.3).

``PH_m`` is obtained by traversing the augmented method type graph ``AG_m``
from the receiver root (``this``): each maximal root-to-leaf navigation path
``f1.f2.....fn`` is one prefetching hint; hints whose first step is a
collection predict that *all its elements* are accessed.

Two policies for runtime-dependent behavior (section 4.4):

  * ``include`` (CAPre's implementation choice): branch-dependent navigations
    are included — the union of all branches is prefetched;
  * ``exclude``: subtrees below the first branch-dependent navigation are
    dropped (reproduces the conservative PH_m printed in section 4.3).

Finally, the all-callers deduplication of section 5.1.3: a hint of ``m`` is
removed when every method that invokes ``m`` already prefetches the same
objects (its own hint set covers the grafted copy), which "brings the
prefetching forward" while keeping accuracy unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from . import lang
from .type_graph import (
    CAPreAnalysis,
    EXCLUDE_BRANCH_DEPENDENT,
    INCLUDE_BRANCH_DEPENDENT,
    MethodGraph,
    Node,
)

Steps = tuple[tuple[str, str], ...]


@dataclass(frozen=True)
class Hint:
    steps: Steps
    # -- static-optimizer annotations (core.opt) ---------------------------
    # All compare=False: hint identity, eq/hash, the all-callers dedup and
    # the replay trace-cache fingerprint stay steps-only — the optimizer
    # decorates hints, it never changes which hints exist.
    #: step indices whose navigated-to object is a known update site — the
    #: prefetch of that object should be read-for-ownership (dirty-allocate)
    rfo_depths: tuple[int, ...] = field(default=(), compare=False)
    #: partial-traversal truncation: at step ``trunc_step`` (a collection),
    #: only the first ``prefix_bound`` elements are predicted
    prefix_bound: Optional[int] = field(default=None, compare=False)
    trunc_step: Optional[int] = field(default=None, compare=False)
    #: static priority from the cost model (higher = dispatch sooner)
    priority: float = field(default=0.0, compare=False)

    def __str__(self) -> str:
        return ".".join(f + ("[]" if c == lang.COLLECTION else "") for f, c in self.steps)

    __repr__ = __str__

    @property
    def has_collection(self) -> bool:
        return any(c == lang.COLLECTION for _f, c in self.steps)

    @property
    def rfo(self) -> bool:
        return bool(self.rfo_depths)

    @property
    def truncated(self) -> bool:
        return self.prefix_bound is not None


def _included_nodes(g: MethodGraph, policy: str):
    """DFS over this-rooted nodes honoring the branch policy; yields
    (node, steps) where ``steps`` is the full path from the root to ``node``
    (inclusive)."""
    stack: list[tuple[Node, Steps]] = [(g.this_root, ())]
    while stack:
        node, steps = stack.pop()
        if node.parent is not None:
            if policy == EXCLUDE_BRANCH_DEPENDENT and node.branch_dependent:
                continue
            yield node, steps
        for child in node.children.values():
            stack.append((child, steps + ((child.field, child.card),)))


def method_paths(g: MethodGraph, policy: str) -> set[Steps]:
    """Prefix-closed set of this-rooted navigation paths under ``policy``."""
    return {steps for _node, steps in _included_nodes(g, policy)}


def method_hints(g: MethodGraph, policy: str) -> tuple[Hint, ...]:
    """PH_m: maximal this-rooted paths (leaves of the included subgraph)."""
    paths = method_paths(g, policy)
    leaves = [p for p in paths if not any(q != p and q[: len(p)] == p for q in paths)]
    return tuple(Hint(p) for p in sorted(leaves, key=str))


@dataclass
class AnalysisReport:
    app_name: str
    policy: str
    graphs: dict[str, MethodGraph]
    full_hints: dict[str, tuple[Hint, ...]]  # PH_m before caller dedup
    hints: dict[str, tuple[Hint, ...]]  # PH_m after caller dedup (section 5.1.3)
    stats: "CorpusStats" = None
    opt: object = None  # core.opt.OptStats once the optimizer has run

    def hints_str(self, key: str) -> set[str]:
        return {str(h) for h in self.hints[key]}

    def full_hints_str(self, key: str) -> set[str]:
        return {str(h) for h in self.full_hints[key]}


@dataclass
class CorpusStats:
    """Reproduces the aggregates of section 4.4 (Table 2)."""

    n_methods: int = 0
    n_methods_no_bd: int = 0
    n_conditionals: int = 0
    n_conditionals_no_bd: int = 0
    n_loops: int = 0
    n_loops_no_bd: int = 0
    n_classes: int = 0

    @property
    def pct_methods_no_bd(self) -> float:
        return 100.0 * self.n_methods_no_bd / max(1, self.n_methods)

    @property
    def pct_conditionals_no_bd(self) -> float:
        return 100.0 * self.n_conditionals_no_bd / max(1, self.n_conditionals)

    @property
    def pct_loops_no_bd(self) -> float:
        return 100.0 * self.n_loops_no_bd / max(1, self.n_loops)


def generate(analysis: CAPreAnalysis, policy: str = INCLUDE_BRANCH_DEPENDENT) -> AnalysisReport:
    graphs = analysis.analyze_all()
    full = {k: method_hints(g, policy) for k, g in graphs.items()}
    paths = {k: method_paths(g, policy) for k, g in graphs.items()}

    final: dict[str, tuple[Hint, ...]] = {}
    for key, hints in full.items():
        final[key] = _dedup_against_callers(analysis, graphs, paths, key, hints)

    stats = CorpusStats(n_classes=len(analysis.app.classes))
    for g in graphs.values():
        stats.n_methods += 1
        stats.n_methods_no_bd += 0 if g.has_branch_dependent() else 1
        stats.n_conditionals += g.n_conditionals
        stats.n_conditionals_no_bd += g.n_conditionals - g.conds_with_bd
        stats.n_loops += g.n_loops
        stats.n_loops_no_bd += g.n_loops - g.loops_with_bd

    return AnalysisReport(
        app_name=analysis.app.name,
        policy=policy,
        graphs=graphs,
        full_hints=full,
        hints=final,
        stats=stats,
    )


def _dedup_against_callers(
    analysis: CAPreAnalysis,
    graphs: dict[str, MethodGraph],
    paths: dict[str, set[Steps]],
    key: str,
    hints: tuple[Hint, ...],
) -> tuple[Hint, ...]:
    """Remove hints found in *all* of the methods that invoke ``key``.

    A caller covers hint ``h`` when some invocation site grafted the callee's
    graph onto a this-rooted receiver whose path prefixed with ``h`` is a path
    the caller itself prefetches."""
    sites = analysis.call_sites.get(key, [])
    if not sites or not hints:
        return hints
    callers = sorted({s.caller for s in sites})
    kept: list[Hint] = []
    for h in hints:
        covered_by_all = True
        for caller in callers:
            caller_graph = graphs.get(caller)
            caller_paths = paths.get(caller, set())
            covered = False
            for s in sites:
                if s.caller != caller or not s.grafted or s.receiver is None:
                    continue
                if caller_graph is None or s.receiver.root() is not caller_graph.this_root:
                    continue
                if s.receiver.path() + h.steps in caller_paths:
                    covered = True
                    break
            if not covered:
                covered_by_all = False
                break
        if not covered_by_all:
            kept.append(h)
    return tuple(kept)


def analyze_application(
    app: lang.Application, policy: str = INCLUDE_BRANCH_DEPENDENT,
    optimize: bool = True,
) -> AnalysisReport:
    """One-call entry point: lower, run Algorithm 1 on every method, generate
    deduplicated prefetching hints, and (unless ``optimize=False``) run the
    static optimizer passes (core.opt) that annotate each hint with RFO
    depths, partial-traversal bounds and a dispatch priority."""
    report = generate(CAPreAnalysis(app), policy)
    if optimize:
        from .opt import optimize_report  # lazy: opt imports this module

        optimize_report(report, app=app)
    return report
