"""capre-lint — Pass 4 of the static hint optimizer: the verifier
(DESIGN.md section 3.9).

Passes 1–3 (:mod:`repro.core.opt`) decorate the analysis output; this pass
*checks* it.  Hints are data that ride from registration time into every
dispatch path, golden artifact and replay fingerprint, so a malformed hint
(schema drift after an app edit, a hand-edited golden, an optimizer
regression) fails loudly here instead of silently mis-prefetching:

  * **schema** — every hint path must type-check against the application
    type graph: each step resolves to a persistent association on the
    walked class (supertype chain included) with the recorded cardinality;
  * **unreachable** — an association whose target class is missing from
    the schema is a dangling edge: the path walks into a type that cannot
    be reached (or even instantiated);
  * **depth** — hint depth is bounded (:data:`MAX_HINT_DEPTH`): the
    analysis cuts recursion, so an over-deep path means graph corruption;
  * **bounds** — optimizer annotations are internally consistent:
    ``rfo_depths`` index real steps, truncation carries both
    ``trunc_step`` (a collection step) and a positive ``prefix_bound``,
    priority sits in (0, 1];
  * **shadowed** — the section 5.1.3 all-callers dedup is re-derived from
    scratch and must reproduce the report's kept set exactly: a kept hint
    every caller covers (or a dropped hint some caller does not) means
    the dedup and the graphs have drifted apart.

``--compare`` diffs the freshly-analyzed hints of every checked app
against the committed golden (``artifacts/analysis/hints.json`` by
default) and fails on any drift — the CI gate that makes hint-set changes
reviewable instead of silent.  ``--write`` regenerates the golden.

Exit codes: 0 clean, 1 lint findings, 2 golden drift (or missing golden).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass
from typing import Callable, Optional

from . import lang
from .hints import AnalysisReport, Hint, _dedup_against_callers, generate, method_paths
from .opt import optimize_report
from .type_graph import CAPreAnalysis, INCLUDE_BRANCH_DEPENDENT

#: deepest hint path the analysis can legitimately emit: recursion is cut
#: at the back edge, so depth is bounded by the longest acyclic navigation
#: chain in the schema — far below this, generously rounded up
MAX_HINT_DEPTH = 16

#: the committed golden hint-set artifact (the ``--compare`` reference)
DEFAULT_GOLDEN = os.path.join("artifacts", "analysis", "hints.json")

#: the apps whose hint sets are golden-gated
DEFAULT_APPS = ("bank", "wordcount", "kmeans", "oo7", "pga")


def _builders() -> dict[str, Callable[[], lang.Application]]:
    from repro.apps import bank, kmeans, oo7, pga, wordcount

    return {
        "bank": bank.build_bank_app,
        "wordcount": wordcount.build_wordcount_app,
        "kmeans": kmeans.build_kmeans_app,
        "oo7": oo7.build_oo7_app,
        "pga": pga.build_pga_app,
    }


@dataclass(frozen=True)
class Finding:
    app: str
    method: str
    hint: str
    kind: str  # schema | unreachable | depth | bounds | shadowed
    message: str

    def __str__(self) -> str:
        where = f"{self.app}:{self.method}"
        if self.hint:
            where += f" {self.hint}"
        return f"[{self.kind}] {where}: {self.message}"


# ---------------------------------------------------------------------------
# per-hint checks
# ---------------------------------------------------------------------------


def _check_path(app: lang.Application, owner: str, h: Hint) -> list[str]:
    """Type-check one hint path against the schema, walking from the
    method's receiver class.  Returns problem strings (empty = clean)."""
    problems: list[str] = []
    cls = owner
    for i, (fld, card) in enumerate(h.steps):
        try:
            spec = app.field_spec(cls, fld)
        except AttributeError:
            problems.append(f"step {i} ({fld}): no field {fld!r} on {cls}")
            break
        if not spec.is_persistent:
            problems.append(
                f"step {i} ({fld}): primitive field, not a persistent association"
            )
            break
        if spec.card != card:
            problems.append(
                f"step {i} ({fld}): cardinality {card!r} but schema says {spec.card!r}"
            )
        if spec.target not in app.classes:
            problems.append(
                f"step {i} ({fld}): unreachable target class {spec.target!r}"
            )
            break
        cls = spec.target
    return problems


def _check_bounds(h: Hint) -> list[str]:
    """Optimizer-annotation consistency for one hint."""
    problems: list[str] = []
    n = len(h.steps)
    for d in h.rfo_depths:
        if not (0 <= d < n):
            problems.append(f"rfo depth {d} outside [0, {n})")
    if tuple(sorted(set(h.rfo_depths))) != tuple(h.rfo_depths):
        problems.append(f"rfo depths {h.rfo_depths} not sorted/unique")
    if (h.trunc_step is None) != (h.prefix_bound is None):
        problems.append(
            f"truncation half-set: trunc_step={h.trunc_step} "
            f"prefix_bound={h.prefix_bound}"
        )
    if h.trunc_step is not None:
        if not (0 <= h.trunc_step < n):
            problems.append(f"trunc step {h.trunc_step} outside [0, {n})")
        elif h.steps[h.trunc_step][1] != lang.COLLECTION:
            problems.append(
                f"trunc step {h.trunc_step} ({h.steps[h.trunc_step][0]}) "
                "is not a collection step"
            )
    if h.prefix_bound is not None and h.prefix_bound <= 0:
        problems.append(f"non-positive prefix bound {h.prefix_bound}")
    if not (0.0 < h.priority <= 1.0):
        problems.append(f"priority {h.priority} outside (0, 1]")
    return problems


def _check_shadowing(analysis: CAPreAnalysis,
                     report: AnalysisReport) -> list[Finding]:
    """Re-derive the all-callers dedup from the graphs and demand it
    reproduces the report's kept hint sets exactly."""
    findings: list[Finding] = []
    paths = {k: method_paths(g, report.policy) for k, g in report.graphs.items()}
    for key, full in report.full_hints.items():
        rederived = {
            str(h) for h in _dedup_against_callers(
                analysis, report.graphs, paths, key, full)
        }
        kept = report.hints_str(key)
        for extra in sorted(kept - rederived):
            findings.append(Finding(
                report.app_name, key, extra, "shadowed",
                "kept hint is covered by every caller (dedup missed it)"))
        for missing in sorted(rederived - kept):
            findings.append(Finding(
                report.app_name, key, missing, "shadowed",
                "dropped hint is NOT covered by every caller (over-dedup)"))
    return findings


# ---------------------------------------------------------------------------
# per-app lint
# ---------------------------------------------------------------------------


def lint_report(app: lang.Application, analysis: CAPreAnalysis,
                report: AnalysisReport) -> list[Finding]:
    """All checks over one app's analyzed + optimized report."""
    findings: list[Finding] = []
    for key, hints in report.hints.items():
        owner = key.split(".", 1)[0]
        if owner not in app.classes:
            findings.append(Finding(
                report.app_name, key, "", "schema",
                f"method key owner {owner!r} not in schema"))
            continue
        for h in hints:
            for msg in _check_path(app, owner, h):
                kind = "unreachable" if "unreachable" in msg else "schema"
                findings.append(Finding(report.app_name, key, str(h), kind, msg))
            if len(h.steps) > MAX_HINT_DEPTH:
                findings.append(Finding(
                    report.app_name, key, str(h), "depth",
                    f"depth {len(h.steps)} exceeds bound {MAX_HINT_DEPTH}"))
            for msg in _check_bounds(h):
                findings.append(Finding(report.app_name, key, str(h), "bounds", msg))
    findings.extend(_check_shadowing(analysis, report))
    return findings


def analyze(name: str, policy: str = INCLUDE_BRANCH_DEPENDENT
            ) -> tuple[lang.Application, CAPreAnalysis, AnalysisReport]:
    """Build + analyze + optimize one catalog app, keeping the analysis
    object (its call sites feed the shadowing re-derivation)."""
    app = _builders()[name]()
    analysis = CAPreAnalysis(app)
    report = generate(analysis, policy)
    optimize_report(report, app=app)
    return app, analysis, report


# ---------------------------------------------------------------------------
# golden hint-set artifact
# ---------------------------------------------------------------------------


def hint_record(h: Hint) -> dict:
    """The JSON shape one hint takes in the golden (annotations included:
    optimizer drift is hint drift)."""
    return {
        "path": str(h),
        "rfo_depths": list(h.rfo_depths),
        "trunc_step": h.trunc_step,
        "prefix_bound": h.prefix_bound,
        "priority": h.priority,
    }


def golden_payload(reports: dict[str, AnalysisReport]) -> dict:
    return {
        "version": 1,
        "apps": {
            name: {
                "stats": report.opt.snapshot() if report.opt else {},
                "methods": {
                    key: [hint_record(h)
                          for h in sorted(hints, key=str)]
                    for key, hints in sorted(report.hints.items())
                    if hints
                },
            }
            for name, report in sorted(reports.items())
        },
    }


def diff_golden(golden: dict, current: dict) -> list[str]:
    """Human-readable structural drift between two golden payloads (empty
    list = identical hint sets)."""
    drift: list[str] = []
    g_apps, c_apps = golden.get("apps", {}), current.get("apps", {})
    for name in sorted(set(g_apps) | set(c_apps)):
        if name not in c_apps:
            drift.append(f"{name}: app missing from current analysis")
            continue
        if name not in g_apps:
            drift.append(f"{name}: app not in golden (re-run --write?)")
            continue
        g_m, c_m = g_apps[name].get("methods", {}), c_apps[name].get("methods", {})
        for key in sorted(set(g_m) | set(c_m)):
            g_hints = {h["path"]: h for h in g_m.get(key, [])}
            c_hints = {h["path"]: h for h in c_m.get(key, [])}
            for path in sorted(g_hints.keys() - c_hints.keys()):
                drift.append(f"{name}:{key}: hint disappeared: {path}")
            for path in sorted(c_hints.keys() - g_hints.keys()):
                drift.append(f"{name}:{key}: new hint: {path}")
            for path in sorted(g_hints.keys() & c_hints.keys()):
                if g_hints[path] != c_hints[path]:
                    drift.append(
                        f"{name}:{key}: {path}: annotations changed "
                        f"{g_hints[path]} -> {c_hints[path]}")
    return drift


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="capre-lint",
        description="verify analyzed prefetch hints and gate the golden hint-set",
    )
    ap.add_argument("--apps", default=",".join(DEFAULT_APPS),
                    help="comma-separated catalog apps to lint")
    ap.add_argument("--policy", default=INCLUDE_BRANCH_DEPENDENT,
                    help="branch-dependence policy (include/exclude)")
    ap.add_argument("--golden", default=DEFAULT_GOLDEN,
                    help="golden hint-set JSON path")
    ap.add_argument("--write", action="store_true",
                    help="regenerate the golden from the current analysis")
    ap.add_argument("--compare", action="store_true",
                    help="fail (exit 2) if current hints drift from the golden")
    args = ap.parse_args(argv)

    apps = tuple(a for a in args.apps.split(",") if a)
    reports: dict[str, AnalysisReport] = {}
    findings: list[Finding] = []
    for name in apps:
        app, analysis, report = analyze(name, policy=args.policy)
        reports[name] = report
        app_findings = lint_report(app, analysis, report)
        findings.extend(app_findings)
        shadowed = sum(
            len(report.full_hints[k]) - len(report.hints[k])
            for k in report.full_hints
        )
        s = report.opt
        print(f"{name}: methods={s.methods} hints={s.hints} "
              f"rfo={s.rfo_hints} truncated={s.truncated_hints} "
              f"caller-shadowed={shadowed} findings={len(app_findings)}")

    for f in findings:
        print(str(f), file=sys.stderr)

    current = golden_payload(reports)
    if args.write:
        os.makedirs(os.path.dirname(args.golden) or ".", exist_ok=True)
        with open(args.golden, "w") as fh:
            json.dump(current, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.golden}")

    rc = 1 if findings else 0
    if args.compare:
        if not os.path.exists(args.golden):
            print(f"# golden {args.golden} missing — run --write and commit it",
                  file=sys.stderr)
            return 2
        with open(args.golden) as fh:
            golden = json.load(fh)
        drift = diff_golden(golden, current)
        for line in drift:
            print(f"drift: {line}", file=sys.stderr)
        if drift:
            print(f"# {len(drift)} hint-set drift(s) vs {args.golden}; "
                  "if intended, regenerate with --write and commit",
                  file=sys.stderr)
            return 2
        print(f"# hint sets match {args.golden}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
