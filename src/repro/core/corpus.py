"""Seeded random application generator — our stand-in for the SF110 corpus
(paper section 4.4): a population of OO applications with varying schema
sizes, navigation patterns, conditionals, loops and branching instructions,
used to reproduce the Table 2 statistics and the Figure 8 analysis-time
distribution.

Generated applications are *analyzable* (schema-consistent navigations) but
not meant to be executed.
"""

from __future__ import annotations

import random

from . import lang
from .lang import (
    Application,
    Break,
    Call,
    ClassDef,
    Compute,
    Const,
    ExprStmt,
    FieldSpec,
    ForEach,
    Get,
    If,
    Let,
    MethodDef,
    Return,
    This,
    Var,
)


def generate_app(
    seed: int,
    n_classes: int = 8,
    methods_per_class: int = 3,
    stmts_per_method: int = 6,
) -> Application:
    rng = random.Random(seed)
    names = [f"C{i}" for i in range(n_classes)]
    classes: dict[str, ClassDef] = {}

    # --- schema ---------------------------------------------------------
    for name in names:
        fields = {}
        n_persistent = rng.randint(1, 3)
        for j in range(n_persistent):
            card = lang.COLLECTION if rng.random() < 0.3 else lang.SINGLE
            fields[f"f{j}"] = FieldSpec(f"f{j}", target=rng.choice(names), card=card)
        fields["p0"] = FieldSpec("p0")
        classes[name] = ClassDef(name, fields)

    # --- method bodies ----------------------------------------------------
    def nav_chain(cls: str, depth: int) -> tuple[lang.Expr, str]:
        """A chain of single-association navigations from this."""
        expr: lang.Expr = This()
        cur = cls
        for _ in range(depth):
            singles = [f for f in classes[cur].fields.values() if f.is_persistent and f.card == lang.SINGLE]
            if not singles:
                break
            f = rng.choice(singles)
            expr = Get(expr, f.name)
            cur = f.target
        return expr, cur

    def random_stmts(cls: str, depth_budget: int) -> list[lang.Stmt]:
        stmts: list[lang.Stmt] = []
        for _ in range(rng.randint(1, stmts_per_method)):
            roll = rng.random()
            if roll < 0.35:
                expr, _t = nav_chain(cls, rng.randint(1, 3))
                stmts.append(ExprStmt(expr))
            elif roll < 0.55:
                colls = [f for f in classes[cls].fields.values() if f.card == lang.COLLECTION]
                if colls and depth_budget > 0:
                    f = rng.choice(colls)
                    inner: list[lang.Stmt] = [ExprStmt(Get(Var("e"), "p0"))]
                    singles = [
                        g for g in classes[f.target].fields.values()
                        if g.is_persistent and g.card == lang.SINGLE
                    ]
                    if singles:
                        inner.append(ExprStmt(Get(Var("e"), rng.choice(singles).name)))
                    if rng.random() < 0.25:
                        inner.append(
                            If(Compute(lambda: False, (), "cond"), then=[Break()])
                        )
                    stmts.append(ForEach("e", This(), f.name, inner))
            elif roll < 0.8 and depth_budget > 0:
                # conditional: sometimes both branches access the same
                # navigation (the common case per the paper), sometimes not
                expr_a, _ = nav_chain(cls, 1)
                same = rng.random() < 0.6
                then = [ExprStmt(expr_a)]
                els = [ExprStmt(expr_a)] if same else random_stmts(cls, depth_budget - 1)
                stmts.append(If(Compute(lambda: True, (), "cond"), then=then, els=els))
            else:
                mcls = rng.choice(names)
                if classes[mcls].methods:
                    mname = rng.choice(list(classes[mcls].methods))
                    singles = [
                        f for f in classes[cls].fields.values()
                        if f.is_persistent and f.card == lang.SINGLE and f.target == mcls
                    ]
                    if singles:
                        stmts.append(ExprStmt(Call(Get(This(), singles[0].name), mname)))
        if not stmts:
            stmts.append(ExprStmt(Const(0)))
        return stmts

    for name in names:
        for k in range(rng.randint(1, methods_per_class)):
            classes[name].add_method(MethodDef(f"m{k}", params=(), body=random_stmts(name, 2)))

    return Application(name=f"synthetic_{seed}", classes=classes)


def generate_corpus(n_apps: int = 40, base_seed: int = 100) -> list[Application]:
    rng = random.Random(base_seed)
    apps = []
    for i in range(n_apps):
        apps.append(
            generate_app(
                seed=base_seed + i,
                n_classes=rng.randint(3, 30),
                methods_per_class=rng.randint(1, 6),
                stmts_per_method=rng.randint(3, 10),
            )
        )
    return apps
