"""Lowering from the OO application AST (``core.lang``) to the Wala-like IR
(``core.ir``) — the analogue of Wala producing an IR from Java source
(paper section 5.1.1, Listing 2).

``ForEach`` loops are lowered to the iterator()/hasNext()/conditionalbranch/
next()/goto pattern shown in the paper's Listing 2; the ``next()`` invocation
inside the loop is what Algorithm 1 recognizes as a collection association
navigation.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from . import ir, lang


@dataclass
class _Ctx:
    app: lang.Application
    instrs: list[ir.Instr] = field(default_factory=list)
    var_counter: int = 0
    # static types of variables (var id -> class name or None for primitives)
    var_types: dict[str, Optional[str]] = field(default_factory=dict)
    # local name -> var id
    env: dict[str, str] = field(default_factory=dict)
    branch_path: tuple[tuple[int, int, int], ...] = ()
    loop_path: tuple[int, ...] = ()
    cond_counter: int = 0
    loop_counter: int = 0
    this_var: str = "v1"

    def fresh(self, typ: Optional[str]) -> str:
        self.var_counter += 1
        v = f"v{self.var_counter}"
        self.var_types[v] = typ
        return v

    def emit(self, itype: str, params=None, def_var=None, used=()) -> ir.Instr:
        instr = ir.Instr(
            ii=len(self.instrs) + 1,
            itype=itype,
            params=params or {},
            def_var=def_var,
            used_vars=tuple(used),
            branch_path=self.branch_path,
            loop_path=self.loop_path,
        )
        self.instrs.append(instr)
        return instr


def lower_method(app: lang.Application, m: lang.MethodDef) -> ir.MethodIR:
    ctx = _Ctx(app=app)
    params: list[tuple[str, str, Optional[str]]] = []
    this = ctx.fresh(m.owner)
    ctx.env["this"] = this
    ctx.this_var = this
    params.append((this, "this", m.owner))
    for pname, ptype in m.params:
        v = ctx.fresh(ptype)
        ctx.env[pname] = v
        params.append((v, pname, ptype))
    _lower_block(ctx, m.body)
    return ir.MethodIR(owner=m.owner, name=m.name, params=tuple(params), instrs=ctx.instrs)


def lower_application(app: lang.Application) -> dict[str, ir.MethodIR]:
    return {m.key: lower_method(app, m) for m in app.all_methods()}


# ---------------------------------------------------------------------------


def _lower_block(ctx: _Ctx, stmts: list[lang.Stmt]) -> None:
    for s in stmts:
        _lower_stmt(ctx, s)


def _lower_stmt(ctx: _Ctx, s: lang.Stmt) -> None:
    if isinstance(s, lang.Let):
        v = _lower_expr(ctx, s.expr)
        ctx.env[s.var] = v
    elif isinstance(s, lang.ExprStmt):
        _lower_expr(ctx, s.expr)
    elif isinstance(s, lang.SetField):
        vo = _lower_expr(ctx, s.obj)
        vv = _lower_expr(ctx, s.value)
        owner = ctx.var_types.get(vo)
        spec = ctx.app.field_spec(owner, s.field) if owner else None
        ctx.emit(
            ir.PUTFIELD,
            params={
                "owner": owner,
                "field": s.field,
                "target": spec.target if spec else None,
                "card": spec.card if spec else lang.SINGLE,
                "persistent": bool(spec and spec.is_persistent),
            },
            used=(vo, vv),
        )
    elif isinstance(s, lang.If):
        vc = _lower_expr(ctx, s.cond)
        ctx.emit(ir.CONDBRANCH, params={"src": "if"}, used=(vc,))
        cid = ctx.cond_counter = ctx.cond_counter + 1
        saved = ctx.branch_path
        ctx.branch_path = saved + ((cid, 0, 2),)
        _lower_block(ctx, s.then)
        ctx.branch_path = saved + ((cid, 1, 2),)
        _lower_block(ctx, s.els)
        ctx.branch_path = saved
    elif isinstance(s, lang.While):
        lid = ctx.loop_counter = ctx.loop_counter + 1
        saved = ctx.loop_path
        ctx.loop_path = saved + (lid,)
        vc = _lower_expr(ctx, s.cond)
        ctx.emit(ir.CONDBRANCH, params={"src": "while"}, used=(vc,))
        _lower_block(ctx, s.body)
        ctx.emit(ir.GOTO, params={"src": "while"})
        ctx.loop_path = saved
    elif isinstance(s, lang.ForEach):
        _lower_foreach(ctx, s)
    elif isinstance(s, lang.ForEachLocal):
        vi = _lower_expr(ctx, s.iterable)
        lid = ctx.loop_counter = ctx.loop_counter + 1
        saved = ctx.loop_path
        ctx.loop_path = saved + (lid,)
        velem = ctx.fresh(None)
        ctx.emit(ir.COMPUTE, params={"label": "local-iter"}, def_var=velem, used=(vi,))
        ctx.env[s.var] = velem
        _lower_block(ctx, s.body)
        ctx.emit(ir.GOTO, params={"src": "foreach-local"})
        ctx.loop_path = saved
    elif isinstance(s, lang.Return):
        used = ()
        if s.expr is not None:
            used = (_lower_expr(ctx, s.expr),)
        ctx.emit(ir.RETURN, used=used)
    elif isinstance(s, lang.Break):
        ctx.emit(ir.BREAK)
    elif isinstance(s, lang.Continue):
        ctx.emit(ir.CONTINUE)
    else:  # pragma: no cover
        raise TypeError(f"unknown statement {type(s)}")


def _lower_foreach(ctx: _Ctx, s: lang.ForEach) -> None:
    """Listing-2 pattern: getfield -> iterator() -> hasNext()/condbranch ->
    next() inside the loop -> body -> goto."""
    vo = _lower_expr(ctx, s.obj)
    owner = ctx.var_types.get(vo)
    spec = ctx.app.field_spec(owner, s.field) if owner else None
    target = spec.target if spec else None
    vcoll = ctx.fresh(None)  # the collection itself is not an object node
    ctx.emit(
        ir.GETFIELD,
        params={
            "owner": owner,
            "field": s.field,
            "target": target,
            "card": lang.COLLECTION,
            "persistent": bool(spec and spec.is_persistent),
        },
        def_var=vcoll,
        used=(vo,),
    )
    viter = ctx.fresh(None)
    ctx.emit(ir.ITER_INIT, params={"of": s.field}, def_var=viter, used=(vcoll,))
    lid = ctx.loop_counter = ctx.loop_counter + 1
    saved = ctx.loop_path
    ctx.loop_path = saved + (lid,)
    vhn = ctx.fresh(None)
    ctx.emit(ir.ITER_HASNEXT, def_var=vhn, used=(viter,))
    ctx.emit(ir.CONDBRANCH, params={"src": "foreach"}, used=(vhn,))
    velem = ctx.fresh(target)
    ctx.emit(
        ir.ITER_NEXT,
        params={"owner": owner, "field": s.field, "target": target},
        def_var=velem,
        used=(viter,),
    )
    ctx.env[s.var] = velem
    _lower_block(ctx, s.body)
    ctx.emit(ir.GOTO, params={"src": "foreach"})
    ctx.loop_path = saved


def _lower_expr(ctx: _Ctx, e: lang.Expr) -> str:
    if isinstance(e, lang.This):
        return ctx.this_var
    if isinstance(e, lang.Var):
        if e.name not in ctx.env:
            raise NameError(f"undefined variable {e.name}")
        return ctx.env[e.name]
    if isinstance(e, lang.Const):
        v = ctx.fresh(None)
        ctx.emit(ir.CONST, params={"value": e.value}, def_var=v)
        return v
    if isinstance(e, lang.Get):
        vo = _lower_expr(ctx, e.obj)
        owner = ctx.var_types.get(vo)
        spec = ctx.app.field_spec(owner, e.field) if owner else None
        persistent = bool(spec and spec.is_persistent)
        target = spec.target if persistent else None
        card = spec.card if spec else lang.SINGLE
        v = ctx.fresh(target if (persistent and card == lang.SINGLE) else None)
        ctx.emit(
            ir.GETFIELD,
            params={
                "owner": owner,
                "field": e.field,
                "target": spec.target if spec else None,
                "card": card,
                "persistent": persistent,
            },
            def_var=v,
            used=(vo,),
        )
        return v
    if isinstance(e, lang.Call):
        vo = _lower_expr(ctx, e.obj)
        vargs = [_lower_expr(ctx, a) for a in e.args]
        owner = ctx.var_types.get(vo)
        is_user = owner is not None and owner in ctx.app.classes
        ret_type = None
        if is_user:
            try:
                ret_type = ctx.app.resolve_method(owner, e.method).ret_type
            except AttributeError:
                is_user = False
        v = ctx.fresh(ret_type)
        ctx.emit(
            ir.INVOKE,
            params={"owner": owner, "method": e.method, "is_user": is_user},
            def_var=v,
            used=tuple([vo] + vargs),
        )
        return v
    if isinstance(e, lang.Compute):
        vargs = [_lower_expr(ctx, a) for a in e.args]
        v = ctx.fresh(None)
        ctx.emit(ir.COMPUTE, params={"label": e.label, "fn": e.fn}, def_var=v, used=tuple(vargs))
        return v
    if isinstance(e, lang.New):
        v = ctx.fresh(e.cls)
        ctx.emit(ir.NEW, params={"cls": e.cls}, def_var=v)
        for fname, fexpr in e.inits.items():
            vv = _lower_expr(ctx, fexpr)
            spec = ctx.app.field_spec(e.cls, fname)
            ctx.emit(
                ir.PUTFIELD,
                params={"owner": e.cls, "field": fname, "target": spec.target,
                        "card": spec.card, "persistent": bool(spec and spec.is_persistent)},
                used=(v, vv),
            )
        return v
    raise TypeError(f"unknown expression {type(e)}")  # pragma: no cover
