"""Augmented method type graphs — Algorithm 1 of the paper (section 5.1.2).

For every method ``m`` we construct the augmented method type graph ``AG_m``:

  * single-association nodes from ``getfield`` instructions whose field type
    is a user-defined persistent type;
  * collection-association nodes from ``arrayload`` / ``Iterator.next()``
    instructions inside loop statements;
  * inter-procedural augmentation: the graph of an invoked method is grafted
    onto the navigation that caused the invocation (the receiver), parameter
    nodes are bound to the argument objects, and the callee's return node is
    linked so chained calls (``getAccount().setCustomer(...)``) keep
    navigating (section 4.2.3);
  * branch-dependent marking (section 4.4): navigations inside a conditional
    branch are branch-dependent *unless the same navigation occurs in every
    branch* (the paper's observation that "the accessed objects are the same
    although the methods executed in each branch may be different"); loops
    containing break/continue/return taint every navigation in the loop;
  * overridden methods are never inlined (dynamic binding, section 4.4);
  * recursion is cut at the first back-edge (the paper's benchmarks include
    recursive traversals — OO7, DFS — and each method schedules its own
    prefetching at runtime, so cutting the static graph is sound).

Because each method is analyzed exactly once and memoized, the complexity is
O(|M| * max|I_m|) as stated in section 5.1.4.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from . import ir, lang
from .lower import lower_application

# Branch-dependence policies for hint generation (section 4.4): the published
# CAPre implementation *includes* branch-dependent navigations (union of all
# branches); `exclude` reproduces the conservative variant used for the
# printed PH_m example of section 4.3.
INCLUDE_BRANCH_DEPENDENT = "include"
EXCLUDE_BRANCH_DEPENDENT = "exclude"

BranchPath = tuple[tuple[int, int, int], ...]


@dataclass
class Node:
    nid: int
    field: Optional[str]  # navigation field that reaches this node (None: root)
    card: str  # single | collection
    type_name: Optional[str]
    parent: Optional["Node"] = None
    children: dict[str, "Node"] = field(default_factory=dict)
    # every occurrence that created/merged this navigation:
    #   (branch_path, tainted)  -- tainted = loop-taint or callee-internal dep
    occurrences: set[tuple[BranchPath, bool]] = field(default_factory=set)
    param_index: Optional[int] = None  # set on root nodes (0 == this)
    is_return: bool = False
    # putfield occurrences whose *receiver* is this object — the object is a
    # known update site (the interprocedural write-set of the opt.py RFO
    # pass); same (branch_path, tainted) shape as ``occurrences``
    write_occurrences: set[tuple[BranchPath, bool]] = field(default_factory=set)

    @property
    def written(self) -> bool:
        """True when some execution of the analyzed method may write a field
        of this object (conditional writes count: prefetching for ownership
        ahead of a branchy update site is the whole point of RFO)."""
        return bool(self.write_occurrences)

    @property
    def branch_dependent(self) -> bool:
        if self.parent is None:
            return False
        clean = {bp for (bp, tainted) in self.occurrences if not tainted}
        return not _covers_unconditional(clean)

    def path(self) -> tuple[tuple[str, str], ...]:
        """Navigation steps (field, card) from the root to this node."""
        steps: list[tuple[str, str]] = []
        n: Optional[Node] = self
        while n is not None and n.parent is not None:
            steps.append((n.field, n.card))
            n = n.parent
        return tuple(reversed(steps))

    def root(self) -> "Node":
        n = self
        while n.parent is not None:
            n = n.parent
        return n


def _covers_unconditional(paths: set[BranchPath]) -> bool:
    """True if the set of branch paths covers every execution path: reduce
    {p+(c,0,n), ..., p+(c,n-1,n)} -> {p} to a fixed point and test for ()."""
    if () in paths:
        return True
    if not paths:
        return False
    work = set(paths)
    changed = True
    while changed:
        changed = False
        for p in list(work):
            if not p:
                return True
            prefix, (cid, _, n) = p[:-1], p[-1]
            siblings = [prefix + ((cid, b, n),) for b in range(n)]
            if all(s in work for s in siblings):
                work -= set(siblings)
                work.add(prefix)
                changed = True
        if () in work:
            return True
    return () in work


# ---------------------------------------------------------------------------
# Per-method graph
# ---------------------------------------------------------------------------


@dataclass
class MethodGraph:
    key: str
    roots: list[Node]  # roots[0] == this, then one per declared parameter
    return_nodes: list[Node]
    # statistics for the section 4.4 reproduction
    n_conditionals: int = 0
    n_loops: int = 0
    conds_with_bd: int = 0
    loops_with_bd: int = 0

    @property
    def this_root(self) -> Node:
        return self.roots[0]

    def iter_nodes(self, root: Optional[Node] = None):
        stack = [root] if root is not None else list(self.roots)
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def has_branch_dependent(self) -> bool:
        return any(n.branch_dependent for n in self.iter_nodes() if n.parent is not None)


class _GraphBuilder:
    """One Algorithm-1 pass over a method's IR."""

    def __init__(self, analysis: "CAPreAnalysis", mir: ir.MethodIR):
        self.analysis = analysis
        self.mir = mir
        self._nid = 0
        self.roots: list[Node] = []
        self.return_nodes: list[Node] = []
        # var id -> Node | _CollRef | None (opaque)
        self.var_state: dict[str, object] = {}
        # loops that contain a branching instruction taint all their navigations
        self.tainted_loops = {
            lid
            for instr in mir.instrs
            if instr.itype in ir.BRANCHING and instr.has_loop_parent
            for lid in instr.loop_path
        }
        self.cond_ids: set[int] = set()
        self.loop_ids: set[int] = set()
        self.bd_cond_ids: set[int] = set()
        self.bd_loop_ids: set[int] = set()

    # -- node helpers -----------------------------------------------------

    def _new_node(self, **kw) -> Node:
        self._nid += 1
        return Node(nid=self._nid, **kw)

    def create_root(self, type_name: Optional[str], param_index: int) -> Node:
        n = self._new_node(field=None, card=lang.SINGLE, type_name=type_name, param_index=param_index)
        self.roots.append(n)
        return n

    def nav_child(
        self,
        parent: Node,
        fld: str,
        card: str,
        target: Optional[str],
        occurrence: tuple[BranchPath, bool],
    ) -> Node:
        child = parent.children.get(fld)
        if child is None:
            child = self._new_node(field=fld, card=card, type_name=target, parent=parent)
            parent.children[fld] = child
        child.occurrences.add(occurrence)
        return child

    # -- main pass ---------------------------------------------------------

    def build(self) -> MethodGraph:
        mir = self.mir
        for i, (var, _name, typ) in enumerate(mir.params):
            self.var_state[var] = self.create_root(typ, param_index=i)
        for instr in mir.instrs:
            self._visit(instr)
        g = MethodGraph(
            key=mir.key,
            roots=self.roots,
            return_nodes=self.return_nodes,
            n_conditionals=len(self.cond_ids),
            n_loops=len(self.loop_ids),
        )
        self._finalize_stats(g)
        return g

    def _occurrence(self, instr: ir.Instr, extra_taint: bool = False) -> tuple[BranchPath, bool]:
        tainted = extra_taint or any(l in self.tainted_loops for l in instr.loop_path)
        return (instr.branch_path, tainted)

    def _note_context(self, instr: ir.Instr) -> None:
        for cid, _b, _n in instr.branch_path:
            self.cond_ids.add(cid)
        for lid in instr.loop_path:
            self.loop_ids.add(lid)

    def _visit(self, instr: ir.Instr) -> None:
        self._note_context(instr)
        t = instr.itype
        if t == ir.GETFIELD:
            self._visit_getfield(instr)
        elif t == ir.PUTFIELD:
            self._visit_putfield(instr)
        elif t == ir.ITER_INIT:
            src = self.var_state.get(instr.used_vars[0])
            self.var_state[instr.def_var] = src if isinstance(src, _CollRef) else None
        elif t in (ir.ITER_NEXT, ir.ARRAYLOAD):
            # Table 3: collection element access, only inside a loop statement
            if not instr.has_loop_parent:
                return
            src = self.var_state.get(instr.used_vars[0])
            if isinstance(src, _CollRef):
                node = self.nav_child(
                    src.owner, src.field, lang.COLLECTION, src.target, self._occurrence(instr)
                )
                self.var_state[instr.def_var] = node
        elif t == ir.INVOKE:
            self._visit_invoke(instr)
        elif t == ir.RETURN:
            if instr.used_vars:
                node = self.var_state.get(instr.used_vars[0])
                if isinstance(node, Node):
                    node.is_return = True
                    self.return_nodes.append(node)
        elif t in (ir.COMPUTE, ir.CONST, ir.NEW):
            if instr.def_var is not None:
                self.var_state[instr.def_var] = None

    def _visit_getfield(self, instr: ir.Instr) -> None:
        p = instr.params
        src = self.var_state.get(instr.used_vars[0])
        if not isinstance(src, Node):
            return  # navigation from a non-persistent value: no node
        if not p.get("persistent"):
            return  # primitive fields are not part of the graph (section 4.2.2)
        if p.get("card") == lang.COLLECTION:
            # "accesses a field of type collection. Hence, no changes to AG_m"
            # -- the element access (next/arrayload) creates the node.
            self.var_state[instr.def_var] = _CollRef(src, p["field"], p.get("target"))
            return
        node = self.nav_child(src, p["field"], lang.SINGLE, p.get("target"), self._occurrence(instr))
        self.var_state[instr.def_var] = node

    def _visit_putfield(self, instr: ir.Instr) -> None:
        """Write-set pass: a putfield marks its *receiver* object as a known
        update site.  The written field's own type doesn't matter (writing a
        primitive like ``amount`` dirties the receiver's cache line exactly
        like rewriting an association), so unlike getfield there is no
        persistent-field filter — only the receiver must be a graph node."""
        src = self.var_state.get(instr.used_vars[0])
        if isinstance(src, Node):
            src.write_occurrences.add(self._occurrence(instr))

    def _visit_invoke(self, instr: ir.Instr) -> None:
        p = instr.params
        if not p.get("is_user"):
            if instr.def_var is not None:
                self.var_state[instr.def_var] = None
            return
        owner, mname = p["owner"], p["method"]
        app = self.analysis.app
        try:
            mdef = app.resolve_method(owner, mname)
        except AttributeError:
            return
        callee_key = mdef.key
        receiver = self.var_state.get(instr.used_vars[0])
        receiver_node = receiver if isinstance(receiver, Node) else None
        # section 4.4: never inline overridden methods (dynamic binding).
        if app.is_overridden(owner, mname):
            self.analysis._record_call(callee_key, self.mir.key, grafted=False, reason="overridden")
            if instr.def_var is not None:
                self.var_state[instr.def_var] = None
            return
        callee_graph = self.analysis.graph_of(callee_key)
        if callee_graph is None:  # recursion cut
            self.analysis._record_call(callee_key, self.mir.key, grafted=False, reason="recursion")
            if instr.def_var is not None:
                self.var_state[instr.def_var] = None
            return

        arg_nodes: list[Optional[Node]] = [receiver_node]
        for v in instr.used_vars[1:]:
            st = self.var_state.get(v)
            arg_nodes.append(st if isinstance(st, Node) else None)

        copied: dict[int, Node] = {}
        occ = self._occurrence(instr)
        for i, callee_root in enumerate(callee_graph.roots):
            if i < len(arg_nodes) and arg_nodes[i] is not None:
                # bindParameter: the callee's root/param subtree hangs off the
                # caller's node for the corresponding object.
                self._graft(callee_root, arg_nodes[i], occ, copied)

        self.analysis._record_call(
            callee_key,
            self.mir.key,
            grafted=receiver_node is not None,
            receiver=receiver_node,
        )

        ret: Optional[Node] = None
        for rn in callee_graph.return_nodes:
            if rn.nid in copied:
                ret = copied[rn.nid]
                break
            if rn.parent is None:
                # method returns one of its own parameters verbatim
                idx = rn.param_index or 0
                if idx < len(arg_nodes):
                    ret = arg_nodes[idx]
                    break
        if instr.def_var is not None:
            self.var_state[instr.def_var] = ret

    def _graft(
        self,
        callee_node: Node,
        onto: Node,
        occ: tuple[BranchPath, bool],
        copied: dict[int, Node],
    ) -> None:
        copied[callee_node.nid] = onto
        branch_path, tainted = occ
        if callee_node.write_occurrences:
            # interprocedural write-set propagation: the callee updates this
            # object, so the caller's corresponding node is an update site
            # too.  The callee's own branch numbering is meaningless here, so
            # its conditionality is collapsed into the taint bit (mirroring
            # how child occurrences fold in ``child.branch_dependent``).
            clean = {bp for (bp, t) in callee_node.write_occurrences if not t}
            onto.write_occurrences.add(
                (branch_path, tainted or not _covers_unconditional(clean))
            )
        for child in callee_node.children.values():
            child_occ = (branch_path, tainted or child.branch_dependent)
            new = self.nav_child(onto, child.field, child.card, child.type_name, child_occ)
            self._graft(child, new, (branch_path, tainted), copied)

    def _finalize_stats(self, g: MethodGraph) -> None:
        """Which conditional/loop statements trigger branch-dependent
        navigations (the Table 2 reproduction)."""
        for n in g.iter_nodes():
            if n.parent is None or not n.branch_dependent:
                continue
            for bp, tainted in n.occurrences:
                for cid, _b, _nb in bp:
                    self.bd_cond_ids.add(cid)
                if tainted:
                    # attribute loop taint to the loops the node's occurrences
                    # sit in (conservative: all tainted loops of the method)
                    self.bd_loop_ids |= self.tainted_loops & self.loop_ids
        g.conds_with_bd = len(self.bd_cond_ids & self.cond_ids)
        g.loops_with_bd = len(self.bd_loop_ids & self.loop_ids)


@dataclass
class _CollRef:
    owner: Node
    field: str
    target: Optional[str]


# ---------------------------------------------------------------------------
# Whole-application analysis driver
# ---------------------------------------------------------------------------


@dataclass
class CallSite:
    caller: str
    grafted: bool
    receiver: Optional[Node] = None
    reason: Optional[str] = None


class CAPreAnalysis:
    """Memoized inter-procedural analysis over a whole application.

    ``graph_of`` computes AG_m once per method (O(|M| * max|I_m|) overall,
    section 5.1.4); cycles in the call graph are cut at the back edge.
    """

    def __init__(self, app: lang.Application):
        self.app = app
        self.method_ir = lower_application(app)
        self._graphs: dict[str, MethodGraph] = {}
        self._in_progress: set[str] = set()
        self.call_sites: dict[str, list[CallSite]] = {}

    def _record_call(self, callee: str, caller: str, grafted: bool, receiver=None, reason=None):
        # Self-recursive sites ARE callers for the section 5.1.3 dedup: the
        # recursion cut means the recursive caller's graph does NOT contain
        # the callee's grafted subtree, so it cannot cover the hints — hence
        # recursive methods keep their hints and re-schedule prefetching at
        # every level (the rolling-frontier behavior that gives the paper its
        # OO7 gains).
        self.call_sites.setdefault(callee, []).append(
            CallSite(caller=caller, grafted=grafted, receiver=receiver, reason=reason)
        )

    def graph_of(self, key: str) -> Optional[MethodGraph]:
        if key in self._graphs:
            return self._graphs[key]
        if key in self._in_progress:
            return None  # recursion cut
        if key not in self.method_ir:
            return None
        self._in_progress.add(key)
        try:
            g = _GraphBuilder(self, self.method_ir[key]).build()
        finally:
            self._in_progress.discard(key)
        self._graphs[key] = g
        return g

    def analyze_all(self) -> dict[str, MethodGraph]:
        for key in list(self.method_ir):
            self.graph_of(key)
        return dict(self._graphs)
