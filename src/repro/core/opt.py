"""Static hint optimizer — multi-pass post-processing of the Algorithm-1
analysis output (DESIGN.md section 3.9).

CAPre's raw ``PH_m`` knows *which* objects a method navigates but throws
away three things the rest of the stack needs, all statically derivable
from the same augmented type graphs:

  * **Pass 1 — interprocedural write-set analysis.**  ``type_graph``
    records a ``write_occurrences`` set per node (every ``putfield`` whose
    receiver is that object, propagated through call grafting exactly like
    read occurrences).  This pass projects those marks onto each hint as
    ``rfo_depths``: the step indices whose target object is a known update
    site.  The prefetch path dirty-allocates those lines (read-for-
    ownership) so the later write is a pure hit instead of an ownership
    upgrade / write-allocate miss.

  * **Pass 2 — partial-traversal truncation.**  A collection navigation
    whose *every* occurrence is loop-tainted sits only inside loops that
    provably exit early (break / continue / return — the same taint
    Algorithm 1 computes for branch-dependence).  Predicting "all
    elements" for such a loop floods the cache with objects the method
    never reads; the pass marks the first such collection step with a
    static ``prefix_bound`` (:data:`DEFAULT_PREFIX_BOUND`) so dispatch
    stops after a bounded prefix.

  * **Pass 3 — static cost / priority model.**  Expected fan-out from
    schema cardinalities (:data:`DEFAULT_COLLECTION_FANOUT` per unbounded
    collection step, the prefix bound for truncated ones) gives each hint
    an expected object count; priority is its inverse on a log scale —
    cheap shallow hints are demanded soonest and finish fastest, so
    ``ObjectStore.prefetch_batch`` dispatches them first and
    ``PrefetchRuntime`` can shed the expensive tail under load (the
    multi-tenant admission-control signal).

Pass 4 — the verifier — lives in :mod:`repro.core.lint`.

Annotations ride the existing frozen :class:`~repro.core.hints.Hint` as
``compare=False`` fields, so hint identity (eq/hash, the all-callers
dedup, the replay trace-cache fingerprint) is untouched: the optimizer
decorates hints, it never changes which hints exist.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Optional

from . import lang
from .hints import AnalysisReport, Hint, Steps, _included_nodes
from .type_graph import MethodGraph, Node

#: elements predicted for a provably-partial collection traversal
DEFAULT_PREFIX_BOUND = 8

#: assumed elements per unbounded collection step (the schema carries
#: cardinality *kind*, not counts; this is the cost model's population
#: guess, deliberately round and documented rather than fitted)
DEFAULT_COLLECTION_FANOUT = 16


@dataclass
class OptStats:
    """Per-application summary of what the optimizer passes did."""

    methods: int = 0
    hints: int = 0
    rfo_hints: int = 0  # hints carrying >= 1 RFO step
    truncated_hints: int = 0  # hints carrying a prefix bound
    prefix_bound: int = DEFAULT_PREFIX_BOUND
    mean_priority: float = 0.0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


def hint_cost(steps: Steps, prefix_bound: Optional[int] = None,
              trunc_step: Optional[int] = None,
              fanout: int = DEFAULT_COLLECTION_FANOUT) -> float:
    """Expected number of objects a full expansion of ``steps`` loads:
    collection steps multiply the live frontier by their expected width,
    every step adds the frontier to the total."""
    total, frontier = 0.0, 1.0
    for i, (_fld, card) in enumerate(steps):
        if card == lang.COLLECTION:
            width = prefix_bound if (trunc_step == i and prefix_bound) else fanout
            frontier *= width
        total += frontier
    return total


def hint_priority(cost: float) -> float:
    """Dispatch priority in (0, 1]: inverse log cost, so a depth-1 single
    association scores ~1.0 and a nested-collection flood scores near 0.
    Rounded so golden artifacts are stable across platforms."""
    return round(1.0 / (1.0 + math.log2(1.0 + cost)), 4)


def _node_for(g: MethodGraph, policy: str) -> dict[Steps, Node]:
    return {steps: node for node, steps in _included_nodes(g, policy)}


def _truncation(nodes: dict[Steps, Node], steps: Steps,
                bound: int) -> tuple[Optional[int], Optional[int]]:
    """First collection step whose every occurrence is loop-tainted (the
    loop provably exits early) -> (trunc_step, prefix_bound)."""
    for i in range(len(steps)):
        _fld, card = steps[i]
        if card != lang.COLLECTION:
            continue
        node = nodes.get(steps[: i + 1])
        if node is None or not node.occurrences:
            continue
        if all(tainted for _bp, tainted in node.occurrences):
            return i, bound
    return None, None


def annotate_hint(nodes: dict[Steps, Node], h: Hint,
                  bound: int = DEFAULT_PREFIX_BOUND,
                  fanout: int = DEFAULT_COLLECTION_FANOUT) -> Hint:
    """All three passes for one hint against its method's node map."""
    rfo_depths = tuple(
        i for i in range(len(h.steps))
        if (n := nodes.get(h.steps[: i + 1])) is not None and n.written
    )
    trunc_step, prefix_bound = _truncation(nodes, h.steps, bound)
    cost = hint_cost(h.steps, prefix_bound=prefix_bound,
                     trunc_step=trunc_step, fanout=fanout)
    return replace(
        h,
        rfo_depths=rfo_depths,
        prefix_bound=prefix_bound,
        trunc_step=trunc_step,
        priority=hint_priority(cost),
    )


def optimize_report(report: AnalysisReport, app=None,
                    bound: int = DEFAULT_PREFIX_BOUND,
                    fanout: int = DEFAULT_COLLECTION_FANOUT) -> OptStats:
    """Run passes 1–3 over every method's hints (both the raw ``full_hints``
    and the deduplicated ``hints``), rewriting the report in place with
    annotated hints and recording an :class:`OptStats` on ``report.opt``."""
    stats = OptStats(prefix_bound=bound)
    node_maps = {
        key: _node_for(g, report.policy) for key, g in report.graphs.items()
    }
    for table in (report.full_hints, report.hints):
        for key, hints in table.items():
            nodes = node_maps.get(key, {})
            table[key] = tuple(
                annotate_hint(nodes, h, bound=bound, fanout=fanout) for h in hints
            )
    priorities = []
    for key, hints in report.hints.items():
        stats.methods += 1
        for h in hints:
            stats.hints += 1
            stats.rfo_hints += 1 if h.rfo else 0
            stats.truncated_hints += 1 if h.truncated else 0
            priorities.append(h.priority)
    stats.mean_priority = round(
        sum(priorities) / len(priorities), 4) if priorities else 0.0
    report.opt = stats
    return stats
