"""Data pipeline with CAPre-style background prefetch.

The training data stream is the third "persistent store" in the system
(after parameters and KV caches).  Access to it is *perfectly* predictable
— batch t+1 follows batch t — so, exactly like the paper's generated
prefetch methods, a background producer keeps a bounded queue of
ready-to-consume batches ahead of the train loop, overlapping host-side
batch assembly (and in real deployments, storage reads) with device
compute.  Determinism: batch content is a pure function of (seed, step), so
elastic restarts resume the stream exactly (the step index is in the
checkpoint).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLMSource:
    """Deterministic synthetic token stream: batch = f(seed, step).

    Serves as the corpus stand-in; swap for a real tokenized shard reader
    behind the same (seed, step) -> batch interface."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                 embeds_dim: int = 0, frames: int = 0, mrope: bool = False,
                 active_vocab: int = 512):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.embeds_dim = embeds_dim
        self.frames = frames
        self.mrope = mrope
        self.active_vocab = min(vocab_size, active_vocab) if active_vocab else vocab_size

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.batch, self.seq_len
        # learnable structure (uniform-random tokens would already sit at the
        # ln(V) CE optimum): a noisy affine Markov chain over an active
        # sub-vocabulary.  Restricting the chain to ``active_vocab`` tokens
        # keeps short smoke runs learnable — the model first discovers the
        # support (ln(V) -> ln(A) within a few steps), then the transitions;
        # a chain over all 32k tokens is a permutation table no small token
        # budget can memorize, so the loss never moves.
        V = self.active_vocab
        tokens = np.empty((B, S + 1), np.int32)
        tokens[:, 0] = rng.integers(0, V, size=B)
        noise = rng.random(size=(B, S)) < 0.15
        noise_tok = rng.integers(0, V, size=(B, S), dtype=np.int64)
        for t in range(S):
            nxt = (tokens[:, t].astype(np.int64) * 31 + 17) % V
            tokens[:, t + 1] = np.where(noise[:, t], noise_tok[:, t], nxt).astype(np.int32)
        out = {"inputs": tokens[:, :-1], "targets": tokens[:, 1:]}
        if self.embeds_dim:
            out["embeds"] = rng.normal(0, 0.02, size=(B, S, self.embeds_dim)).astype(np.float32)
            if self.mrope:
                pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None], (B, S))
                out["positions"] = np.broadcast_to(pos[None], (3, B, S)).copy()
        if self.frames:
            out["frames"] = rng.normal(0, 0.02, size=(B, self.frames, self.embeds_dim or 64)).astype(np.float32)
        return out


class DataPipeline:
    """Bounded-queue background prefetcher over a (seed, step)-addressable
    source."""

    def __init__(self, source, start_step: int = 0, prefetch: int = 2,
                 transform=None):
        self.source = source
        self.prefetch = prefetch
        self.transform = transform
        self._q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._step = start_step
        self._produced = 0
        self._thread = threading.Thread(target=self._produce, daemon=True, name="data-prefetch")
        self._thread.start()

    def _produce(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            if self.transform is not None:
                batch = self.transform(batch)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1
            self._produced += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        if self._stop.is_set():
            raise StopIteration
        return self._q.get()

    @property
    def produced(self) -> int:
        return self._produced

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
