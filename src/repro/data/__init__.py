from .pipeline import DataPipeline, SyntheticLMSource  # noqa: F401
