"""Pluggable cache-eviction policies shared by the live store and the
virtual-clock replay engine (DESIGN.md section 3.5).

CAPre's speedups assume prefetched objects *survive* in cache until their
access.  Whether they do is decided by the eviction policy, so the policy is
a first-class, swappable subsystem: ``pos.store.DataService`` (real threads,
real sleeps) and ``predict.evaluate.VirtualReplay`` (deterministic virtual
clock) both drive the classes below, so simulated and measured thrash come
from one code path.

A policy owns only the *ordering metadata* (which resident line to evict
next); residency itself — the cache dict, dirty bits, in-flight loads —
stays with the host.  The host contract, always under the host's cache lock
(policies are not thread-safe on their own):

  * ``note_insert(oid, prefetch=..., used=...)`` — a line became resident;
  * ``note_access(oid, prefetch=...)``           — a resident line was
    touched (``prefetch=True`` for prefetch-path touches, which must not
    count as the application *using* the line);
  * ``pick_victim()``  — choose + forget the line to evict (host removes it);
  * ``note_remove(oid)`` — a line left the cache outside eviction
    (``drop_cache``);
  * ``reset()``        — forget everything, zero counters.

Policies (``make_policy`` / ``POLICIES``):

  ================  ========================================================
  ``lru``           evict the least-recently-touched line (the store's
                    historical behavior; prefetch touches bump recency too)
  ``fifo``          evict in insertion order; touches never reorder
  ``clock``         second-chance FIFO: a touched line gets its reference
                    bit cleared and one more trip around before eviction
  ``lfu``           evict the least-frequently-touched line (ties broken
                    least-recently-used)
  ``prefetch-aware``protect the *oldest* ``window`` not-yet-used prefetched
                    lines (the ones the application will need soonest —
                    prefetchers emit in traversal order); evict used/demand
                    lines LRU-first, then the *newest* unused prefetch
                    (MRU among the flood, the classic sequential-scan
                    anti-LRU move), and only then a protected line
  ================  ========================================================

``protected_evictions`` counts victim selections where the policy passed
over at least one protected prefetched line — the metric that shows the
prefetch-aware policy actually intervened (it lands on the ``Overhead``
ledger and in the replay CSV).

``SharedBudget`` implements the shared-memory-budget mode: instead of a
fixed per-service capacity, every Data Service draws lines from one global
budget and overflow evicts the policy's globally-worst line *wherever it
lives* (policy-mediated stealing).  One policy instance spans all services;
the budget tracks which service owns each resident line and hands the host
``(owner, victim)`` pairs so dirty flushes charge the victim's own disk.
"""

from __future__ import annotations

import threading
from typing import Optional


class EvictionPolicy:
    """Base class: insertion-ordered metadata + counters.  Subclasses
    override ``note_access`` / ``pick_victim``."""

    name = "?"

    def __init__(self, capacity: int = 0):
        self.capacity = capacity  # informational; hosts enforce it
        self._lines: dict[int, None] = {}  # insertion/recency order
        self.protected_evictions = 0

    # -- host contract ------------------------------------------------------

    def note_insert(self, oid: int, prefetch: bool = False, used: bool = False) -> None:
        self._lines[oid] = None

    def note_access(self, oid: int, prefetch: bool = False) -> None:
        """A resident line was touched.  Default: no reordering (FIFO)."""

    def pick_victim(self) -> int:
        """Choose the line to evict and forget its metadata.  Hosts only
        call this while at least one line is resident."""
        victim = next(iter(self._lines))
        del self._lines[victim]
        return victim

    def note_remove(self, oid: int) -> None:
        self._lines.pop(oid, None)

    def reset(self) -> None:
        self._lines.clear()
        self.protected_evictions = 0

    # -- introspection (tests / invariant checks) ---------------------------

    def tracked(self) -> set[int]:
        """The lines this policy believes are resident — property tests
        assert this stays identical to the host's cache membership."""
        return set(self._lines)

    def __len__(self) -> int:
        return len(self._lines)


class FIFOPolicy(EvictionPolicy):
    name = "fifo"


class LRUPolicy(EvictionPolicy):
    name = "lru"

    def note_access(self, oid: int, prefetch: bool = False) -> None:
        self._lines.pop(oid, None)
        self._lines[oid] = None


class ClockPolicy(EvictionPolicy):
    """Second-chance FIFO: the hand sweeps insertion order; a referenced
    line is spared once (bit cleared, moved to the back) instead of
    maintaining strict recency order."""

    name = "clock"

    def __init__(self, capacity: int = 0):
        super().__init__(capacity)
        self._ref: dict[int, bool] = {}

    def note_insert(self, oid: int, prefetch: bool = False, used: bool = False) -> None:
        super().note_insert(oid, prefetch=prefetch, used=used)
        self._ref[oid] = False

    def note_access(self, oid: int, prefetch: bool = False) -> None:
        self._ref[oid] = True

    def pick_victim(self) -> int:
        while True:
            oid = next(iter(self._lines))
            if self._ref.get(oid, False):
                self._ref[oid] = False
                del self._lines[oid]
                self._lines[oid] = None  # one more trip around
                continue
            del self._lines[oid]
            self._ref.pop(oid, None)
            return oid

    def note_remove(self, oid: int) -> None:
        super().note_remove(oid)
        self._ref.pop(oid, None)

    def reset(self) -> None:
        super().reset()
        self._ref.clear()


class LFUPolicy(EvictionPolicy):
    name = "lfu"

    def __init__(self, capacity: int = 0):
        super().__init__(capacity)
        self._freq: dict[int, int] = {}

    def note_insert(self, oid: int, prefetch: bool = False, used: bool = False) -> None:
        super().note_insert(oid, prefetch=prefetch, used=used)
        self._freq[oid] = 1

    def note_access(self, oid: int, prefetch: bool = False) -> None:
        self._freq[oid] = self._freq.get(oid, 0) + 1
        self._lines.pop(oid, None)  # keep recency for tie-breaks
        self._lines[oid] = None

    def pick_victim(self) -> int:
        # least frequency, ties least-recently-used; O(n) scan is fine at
        # the line counts these caches run (the replay sweeps <= a few
        # hundred lines)
        victim = min(self._lines, key=lambda o: self._freq.get(o, 0))
        del self._lines[victim]
        self._freq.pop(victim, None)
        return victim

    def note_remove(self, oid: int) -> None:
        super().note_remove(oid)
        self._freq.pop(oid, None)

    def reset(self) -> None:
        super().reset()
        self._freq.clear()


class PrefetchAwarePolicy(EvictionPolicy):
    """Protect not-yet-used prefetched lines for a bounded window.

    Prefetchers emit lines in traversal order, so under a flood the *oldest*
    unused prefetched lines are exactly the ones the application will touch
    next — and plain LRU evicts them first (sequential floods are LRU's
    pathological case).  This policy keeps a bounded window of the oldest
    unused prefetched lines resident; victim preference:

      1. unused prefetched lines *beyond* the protection window, newest
         first — the tail of a flood is bypassed rather than allowed to
         thrash either the flood's head or the application's working set;
      2. then used / demand-loaded lines, least-recently-used (so a demand
         line inserted into a cache full of protected prefetches never
         evicts itself while flood tail exists);
      3. only when every resident line is protected, fall back to the
         oldest prefetched line (capacity is a hard bound).

    A line leaves the protected class the moment the application uses it.
    ``window`` bounds how many unused prefetched lines are protected at
    once; the default — half the cache capacity — splits the cache between
    the flood head and the re-accessed working set, which on the benchmark
    traces dominates both the whole-cache window (starves reuse-heavy
    traversals like oo7) and tick-based expiry (gives up the flood head
    before the application reaches it)."""

    name = "prefetch-aware"

    def __init__(self, capacity: int = 0, window: Optional[int] = None):
        super().__init__(capacity)
        self.window = window if window is not None else max(1, capacity // 2)
        self._recency: dict[int, None] = {}  # used/demand lines, LRU order
        self._pending: dict[int, None] = {}  # unused prefetched, insert order

    def note_insert(self, oid: int, prefetch: bool = False, used: bool = False) -> None:
        super().note_insert(oid, prefetch=prefetch, used=used)
        if prefetch and not used:
            self._pending[oid] = None
        else:
            self._recency[oid] = None

    def note_access(self, oid: int, prefetch: bool = False) -> None:
        if oid not in self._lines:
            return
        if not prefetch and oid in self._pending:
            # the application used the prefetched line: protection ends
            del self._pending[oid]
        if oid not in self._pending:
            self._recency.pop(oid, None)
            self._recency[oid] = None

    def pick_victim(self) -> int:
        # protected_evictions counts evictions where at least one protected
        # (in-window, not-yet-used prefetched) line was spared
        if len(self._pending) > self.window:
            victim = next(reversed(self._pending))  # newest beyond the window
            del self._pending[victim]
            self.protected_evictions += 1
        elif self._recency:
            victim = next(iter(self._recency))
            del self._recency[victim]
            if self._pending:
                self.protected_evictions += 1
        else:
            victim = next(iter(self._pending))  # forced: everything protected
            del self._pending[victim]
        del self._lines[victim]
        return victim

    def note_remove(self, oid: int) -> None:
        super().note_remove(oid)
        self._recency.pop(oid, None)
        self._pending.pop(oid, None)

    def reset(self) -> None:
        super().reset()
        self._recency.clear()
        self._pending.clear()


POLICIES: dict[str, type[EvictionPolicy]] = {
    cls.name: cls
    for cls in (LRUPolicy, FIFOPolicy, ClockPolicy, LFUPolicy, PrefetchAwarePolicy)
}

DEFAULT_POLICY = "lru"


def make_policy(name: str = DEFAULT_POLICY, capacity: int = 0, **kwargs) -> EvictionPolicy:
    try:
        cls = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown eviction policy {name!r}; available: {sorted(POLICIES)}")
    return cls(capacity=capacity, **kwargs)


class SharedBudget:
    """One global line budget drawn on by every Data Service (the
    shared-memory-budget mode): a single policy instance orders all resident
    lines store-wide, and overflow evicts the globally-worst line wherever
    it lives.  ``owner`` maps each resident oid to the *set* of objects
    holding a copy of its cache line (``DataService`` instances, or
    Data-Service indices in the replay engine) — with replication >= 2 the
    same oid can be resident on several replicas at once (failover and
    hedged reads land second copies), and all copies share one budget line:
    the policy tracks the oid once, and eviction drops every copy together.
    ``lock`` is the one cache lock every service shares in this mode, so
    cross-service victim selection is race-free."""

    def __init__(self, capacity: int, policy: str = DEFAULT_POLICY, **kwargs):
        self.capacity = capacity
        self.policy = make_policy(policy, capacity=capacity, **kwargs)
        self.owner: dict[int, set] = {}
        self.lock = threading.Lock()

    def note_insert(self, oid: int, owner, prefetch: bool = False, used: bool = False) -> None:
        holders = self.owner.get(oid)
        if holders is None:
            self.owner[oid] = {owner}
            self.policy.note_insert(oid, prefetch=prefetch, used=used)
        else:
            # an additional replica copy of an already-tracked line: bump
            # the existing policy entry instead of re-inserting (a second
            # note_insert would double-register the line in stateful
            # policies like prefetch-aware)
            holders.add(owner)
            self.policy.note_access(oid, prefetch=prefetch)

    def note_remove(self, oid: int, owner=None) -> None:
        """One holder dropped its copy (``owner``), or — with no owner —
        the line vanished everywhere.  The policy forgets the oid only when
        the last copy goes: a surviving replica's copy must stay evictable,
        or its next touch resurrects an ownerless policy entry and a later
        ``pick_victim`` crashes on it."""
        holders = self.owner.get(oid)
        if holders is None:
            return
        if owner is not None:
            holders.discard(owner)
        else:
            holders.clear()
        if not holders:
            del self.owner[oid]
            self.policy.note_remove(oid)

    def overflowed(self) -> bool:
        return bool(self.capacity) and len(self.owner) > self.capacity

    def pick_victim(self) -> tuple[set, int]:
        """Choose the globally-worst line; returns the full holder set —
        the caller evicts the line from every holder."""
        victim = self.policy.pick_victim()
        return self.owner.pop(victim), victim

    def reset(self) -> None:
        self.owner.clear()
        self.policy.reset()
