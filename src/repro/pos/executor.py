"""Prefetch execution runtime (paper sections 5.2.2-5.2.3).

Mirrors the generated Java code:

  * a **single-thread** scheduling executor (the injected
    ``Executors.newFixedThreadPool(1)``) runs the generated prefetch methods
    one after another in the background, so the application thread is never
    interrupted;
  * inside a prefetch method, collection hints fan out over a **shared
    parallel pool** (the JVM parallel-streams ForkJoin pool; its size is the
    number of cores).  Fan-out tasks are non-blocking — a task loads its
    object and submits its children — so nested collections cannot starve
    the bounded pool.

Dispatch granularity is the caller's choice: ``fan_out`` submits one task
per item (the historical per-oid dispatch), ``submit`` submits a single
task for an already-grouped batch (``ObjectStore.prefetch_batch`` uses one
per Data Service).  Every submission is tracked so ``drain`` knows when the
runtime is idle, and ``hard_drain`` can cancel work that never started —
straggler tasks from one benchmark repetition used to keep running into
the next because ``drain``'s timeout result was silently ignored.
"""

from __future__ import annotations

import threading
import warnings
from concurrent.futures import ThreadPoolExecutor


class PrefetchRuntime:
    def __init__(self, parallel_workers: int = 8,
                 max_outstanding: int = 0, admission_threshold: float = 0.0):
        self._scheduler = ThreadPoolExecutor(max_workers=1, thread_name_prefix="prefetch-sched")
        self._pool = ThreadPoolExecutor(max_workers=parallel_workers, thread_name_prefix="prefetch-par")
        self.parallel_workers = parallel_workers
        self._outstanding = 0
        self._lock = threading.Lock()
        self._idle = threading.Event()
        self._idle.set()
        self._futures: set = set()
        self.scheduled = 0
        self.submitted_tasks = 0  # every executor submission (sched + pool)
        # admission control (static-optimizer priority signal): when more
        # than ``max_outstanding`` tasks are outstanding, only batches whose
        # priority clears ``admission_threshold`` are admitted — the
        # expensive tail is shed instead of queueing unboundedly.
        # max_outstanding == 0 disables shedding (the default: the paper's
        # runtime never drops work).
        self.max_outstanding = max_outstanding
        self.admission_threshold = admission_threshold
        self.admission_dropped = 0  # batches shed by admission control

    # -- task accounting -----------------------------------------------------

    def _inc(self) -> None:
        with self._lock:
            self._outstanding += 1
            self.submitted_tasks += 1
            self._idle.clear()

    def _dec(self) -> None:
        with self._lock:
            self._outstanding -= 1
            if self._outstanding == 0:
                self._idle.set()

    def _wrap(self, fn, *args):
        try:
            fn(*args)
        finally:
            self._dec()

    def _track(self, fut) -> None:
        with self._lock:
            self._futures.add(fut)
        fut.add_done_callback(self._untrack)

    def _untrack(self, fut) -> None:
        with self._lock:
            self._futures.discard(fut)
        if fut.cancelled():
            # the wrapped fn never ran, so its _dec never fired
            self._dec()

    # -- API -----------------------------------------------------------------

    def stats(self) -> dict:
        """Queue-depth snapshot for the observability registry (a Session
        registers this as a ``runtime`` source)."""
        with self._lock:
            return {
                "scheduled": self.scheduled,
                "submitted_tasks": self.submitted_tasks,
                "outstanding": self._outstanding,
                "admission_dropped": self.admission_dropped,
            }

    def admit(self, priority: float = 0.0) -> bool:
        """Admission decision for a prefetch batch carrying a static
        ``priority`` (core.opt's cost model, higher = cheaper/sooner
        demanded).  Always True while the runtime has headroom; once
        ``max_outstanding`` tasks are outstanding only priorities >=
        ``admission_threshold`` get in."""
        if not self.max_outstanding:
            return True
        with self._lock:
            if self._outstanding < self.max_outstanding:
                return True
            if priority >= self.admission_threshold:
                return True
            self.admission_dropped += 1
            return False

    def schedule(self, fn) -> None:
        """Submit a generated prefetch method to the background executor
        (the paper's injected ``prefetchingExecutor.submit``)."""
        self.scheduled += 1
        self._inc()
        self._track(self._scheduler.submit(self._wrap, fn))

    def submit(self, fn, *args) -> None:
        """Submit ONE task to the shared parallel pool — the batched
        dispatch entry point (one grouped request per Data Service instead
        of one task per oid).  Non-blocking."""
        self._inc()
        self._track(self._pool.submit(self._wrap, fn, *args))

    def fan_out(self, fn, items) -> None:
        """Parallel-streams analogue: run ``fn(item)`` on the shared pool.
        Non-blocking: returns immediately."""
        for it in items:
            self._inc()
            self._track(self._pool.submit(self._wrap, fn, it))

    def drain(self, timeout: float = 60.0) -> bool:
        """Wait until all scheduled prefetch work has finished.  Returns
        False on timeout — callers that reset shared state next should
        treat that as a leak (see ``hard_drain``)."""
        return self._idle.wait(timeout)

    def hard_drain(self, timeout: float = 5.0) -> bool:
        """Drain, and on timeout cancel every queued-but-unstarted task so
        stragglers cannot touch store state later.  Already-running tasks
        cannot be interrupted — the final wait gives them ``timeout`` more
        seconds to finish."""
        if self._idle.wait(timeout):
            return True
        with self._lock:
            pending = list(self._futures)
        for fut in pending:
            fut.cancel()
        return self._idle.wait(timeout)

    def shutdown(self, timeout: float = 5.0) -> None:
        if not self.hard_drain(timeout):
            warnings.warn(
                f"prefetch runtime still busy after {timeout}s at shutdown; "
                "running straggler tasks will be awaited by the executor",
                RuntimeWarning,
                stacklevel=2,
            )
        self._scheduler.shutdown(wait=True, cancel_futures=True)
        self._pool.shutdown(wait=True, cancel_futures=True)
