"""Prefetch execution runtime (paper sections 5.2.2-5.2.3).

Mirrors the generated Java code:

  * a **single-thread** scheduling executor (the injected
    ``Executors.newFixedThreadPool(1)``) runs the generated prefetch methods
    one after another in the background, so the application thread is never
    interrupted;
  * inside a prefetch method, collection hints fan out over a **shared
    parallel pool** (the JVM parallel-streams ForkJoin pool; its size is the
    number of cores).  Fan-out tasks are non-blocking — a task loads its
    object and submits its children — so nested collections cannot starve
    the bounded pool.
"""

from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor


class PrefetchRuntime:
    def __init__(self, parallel_workers: int = 8):
        self._scheduler = ThreadPoolExecutor(max_workers=1, thread_name_prefix="prefetch-sched")
        self._pool = ThreadPoolExecutor(max_workers=parallel_workers, thread_name_prefix="prefetch-par")
        self._outstanding = 0
        self._lock = threading.Lock()
        self._idle = threading.Event()
        self._idle.set()
        self.scheduled = 0

    # -- task accounting -----------------------------------------------------

    def _inc(self) -> None:
        with self._lock:
            self._outstanding += 1
            self._idle.clear()

    def _dec(self) -> None:
        with self._lock:
            self._outstanding -= 1
            if self._outstanding == 0:
                self._idle.set()

    def _wrap(self, fn, *args):
        try:
            fn(*args)
        finally:
            self._dec()

    # -- API -----------------------------------------------------------------

    def schedule(self, fn) -> None:
        """Submit a generated prefetch method to the background executor
        (the paper's injected ``prefetchingExecutor.submit``)."""
        self.scheduled += 1
        self._inc()
        self._scheduler.submit(self._wrap, fn)

    def fan_out(self, fn, items) -> None:
        """Parallel-streams analogue: run ``fn(item)`` on the shared pool.
        Non-blocking: returns immediately."""
        for it in items:
            self._inc()
            self._pool.submit(self._wrap, fn, it)

    def drain(self, timeout: float = 60.0) -> bool:
        """Wait until all scheduled prefetch work has finished."""
        return self._idle.wait(timeout)

    def shutdown(self) -> None:
        self.drain(timeout=5.0)
        self._scheduler.shutdown(wait=True, cancel_futures=True)
        self._pool.shutdown(wait=True, cancel_futures=True)
