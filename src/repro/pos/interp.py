"""AST interpreter: executes ``core.lang`` applications against the
distributed POS with full cost accounting.

This plays the role of the JVM running the (injected) application inside
dataClay's Data Services:

  * navigating an association redirects execution to the owning Data Service
    and ensures the object is in its memory (``ObjectStore.app_access``);
  * on entry to a registered method the injected scheduling submits the
    generated prefetch method to the background executor (Listing 5) — the
    ``Session`` decides per the configured prefetch mode;
  * primitive field reads touch the already-loaded payload; writes go
    through ``ObjectStore.app_write`` — write-allocate through the owning
    Data Service's cache, dirty bit, deferred write-back on eviction (what
    dominates OO7's t2 traversals under bounded caches);
  * dynamic dispatch resolves methods from the *runtime* class, so
    polymorphic schemas (OO7 assemblies) behave exactly like in Java.
"""

from __future__ import annotations

import itertools
import sys
from dataclasses import dataclass
from typing import Any, Optional

from repro.core import lang
from .store import ExecutionContext, ObjectStore, PersistentObject

# Deeply recursive traversals (OO7, PGA's DFS) cost ~12 Python frames per
# interpreted call — the JVM equivalent is a large thread stack.  Pure-Python
# recursion in CPython 3.12+ does not consume C stack, so this is safe.
if sys.getrecursionlimit() < 200_000:
    sys.setrecursionlimit(200_000)


@dataclass(frozen=True)
class ObjRef:
    oid: int


class _Return(Exception):
    def __init__(self, value):
        self.value = value


class _Break(Exception):
    pass


class _Continue(Exception):
    pass


_volatile_ids = itertools.count(-1, -1)


class Interpreter:
    def __init__(self, session):
        self.session = session
        self.store: ObjectStore = session.store
        self.app: lang.Application = session.app
        self.volatile: dict[int, PersistentObject] = {}

    # -- object helpers ------------------------------------------------------

    def _is_volatile(self, oid: int) -> bool:
        return oid < 0

    def _record(self, oid: int) -> PersistentObject:
        if self._is_volatile(oid):
            return self.volatile[oid]
        return self.store.record(oid)

    def _access(self, ctx: ExecutionContext, oid: int) -> PersistentObject:
        if self._is_volatile(oid):
            return self.volatile[oid]
        return self.store.app_access(ctx, oid)

    # -- execution -----------------------------------------------------------

    def execute(self, root_oid: int, method: str, args: tuple = (), ctx: Optional[ExecutionContext] = None):
        if ctx is None:
            # the context carries the session's tenant identity so every
            # demand span / stall sample this thread produces is attributed
            # to the right tenant even under concurrent sessions
            ctx = ExecutionContext(
                self.store,
                session_label=getattr(self.session, "label", ""),
                stall_hist=getattr(self.session, "_tenant_stall_hist", None),
            )
        return self._invoke(ctx, ObjRef(root_oid), method, tuple(args))

    def _invoke(self, ctx: ExecutionContext, receiver: ObjRef, method: str, args: tuple):
        rec = self._access(ctx, receiver.oid)
        mdef = self.app.resolve_method(rec.cls, method)
        # --- the injected prefetch scheduling (Listing 5) ---
        self.session.on_method_entry(mdef.key, receiver.oid)
        env: dict[str, Any] = {"this": receiver}
        for (pname, _ptype), val in zip(mdef.params, args):
            env[pname] = val
        try:
            self._exec_block(ctx, env, mdef.body)
        except _Return as r:
            return r.value
        return None

    def _exec_block(self, ctx, env, stmts) -> None:
        for s in stmts:
            self._exec_stmt(ctx, env, s)

    def _exec_stmt(self, ctx, env, s) -> None:
        if isinstance(s, lang.Let):
            env[s.var] = self._eval(ctx, env, s.expr)
        elif isinstance(s, lang.ExprStmt):
            self._eval(ctx, env, s.expr)
        elif isinstance(s, lang.SetField):
            self._exec_setfield(ctx, env, s)
        elif isinstance(s, lang.If):
            branch = s.then if self._eval(ctx, env, s.cond) else s.els
            self._exec_block(ctx, env, branch)
        elif isinstance(s, lang.While):
            while self._eval(ctx, env, s.cond):
                try:
                    self._exec_block(ctx, env, s.body)
                except _Break:
                    break
                except _Continue:
                    continue
        elif isinstance(s, lang.ForEach):
            obj = self._eval(ctx, env, s.obj)
            rec = self._record(obj.oid)
            for e in list(rec.fields.get(s.field) or ()):
                self._access(ctx, e)
                env[s.var] = ObjRef(e)
                try:
                    self._exec_block(ctx, env, s.body)
                except _Break:
                    break
                except _Continue:
                    continue
        elif isinstance(s, lang.ForEachLocal):
            items = self._eval(ctx, env, s.iterable)
            for it in list(items or ()):
                env[s.var] = it
                try:
                    self._exec_block(ctx, env, s.body)
                except _Break:
                    break
                except _Continue:
                    continue
        elif isinstance(s, lang.Return):
            raise _Return(self._eval(ctx, env, s.expr) if s.expr is not None else None)
        elif isinstance(s, lang.Break):
            raise _Break()
        elif isinstance(s, lang.Continue):
            raise _Continue()
        else:  # pragma: no cover
            raise TypeError(f"unknown statement {type(s)}")

    def _exec_setfield(self, ctx, env, s: lang.SetField) -> None:
        obj = self._eval(ctx, env, s.obj)
        val = self._eval(ctx, env, s.value)
        rec = self._record(obj.oid)
        spec = self.app.field_spec(rec.cls, s.field)
        if spec.is_persistent:
            if spec.card == lang.COLLECTION:
                rec.fields[s.field] = [v.oid for v in (val or [])]
            else:
                rec.fields[s.field] = val.oid if isinstance(val, ObjRef) else val
        else:
            rec.fields[s.field] = val
        if not self._is_volatile(obj.oid):
            # a write is a demand access: it redirects execution to the
            # owning Data Service and write-allocates through its cache
            self.store.app_write(obj.oid, ctx)

    # -- expressions -----------------------------------------------------------

    def _eval(self, ctx, env, e):
        if isinstance(e, lang.This):
            return env["this"]
        if isinstance(e, lang.Var):
            return env[e.name]
        if isinstance(e, lang.Const):
            return e.value
        if isinstance(e, lang.Get):
            obj = self._eval(ctx, env, e.obj)
            rec = self._record(obj.oid)
            spec = self.app.field_spec(rec.cls, e.field)
            val = rec.fields.get(e.field)
            if not spec.is_persistent:
                return val
            if spec.card == lang.COLLECTION:
                return [ObjRef(o) for o in (val or [])]
            if val is None:
                return None
            self._access(ctx, val)
            return ObjRef(val)
        if isinstance(e, lang.Call):
            obj = self._eval(ctx, env, e.obj)
            args = tuple(self._eval(ctx, env, a) for a in e.args)
            return self._invoke(ctx, obj, e.method, args)
        if isinstance(e, lang.Compute):
            args = [self._eval(ctx, env, a) for a in e.args]
            return e.fn(*args)
        if isinstance(e, lang.New):
            oid = next(_volatile_ids)
            rec = PersistentObject(oid=oid, cls=e.cls, fields={})
            self.volatile[oid] = rec
            ref = ObjRef(oid)
            for fname, fexpr in e.inits.items():
                val = self._eval(ctx, env, fexpr)
                rec.fields[fname] = val.oid if isinstance(val, ObjRef) else val
            return ref
        raise TypeError(f"unknown expression {type(e)}")  # pragma: no cover
