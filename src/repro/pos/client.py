"""dataClay-like client & Logic Module (paper section 6).

Application classes are registered with the Logic Module; CAPre intercepts
the registration, runs the static analysis, and generates + injects the
prefetching methods.  A ``Session`` then executes registered methods against
the store under a prefetching mode resolved through the ``repro.predict``
registry:

  * ``None``           — no prefetching (the paper's baseline),
  * ``"capre"``        — hint-driven prefetching (this paper),
  * ``"rop"``          — Referenced-Objects Predictor at a configurable
                         fetch depth (schema-based baseline),
  * ``"markov-miner"`` — order-k trace mining (monitoring-based baseline),
  * ``"hybrid"``       — static collections + mined single chains,

plus anything else registered via ``repro.predict.register``.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core import lang
from repro.core.hints import AnalysisReport, analyze_application
from repro.core.injection import generate_all
from repro.core.lower import lower_application
from repro.core.type_graph import INCLUDE_BRANCH_DEPENDENT

from .executor import PrefetchRuntime
from .interp import Interpreter
from .store import ObjectStore


@dataclass
class RegisteredApp:
    app: lang.Application
    report: AnalysisReport
    prefetch_methods: dict[str, object]
    lowering_time_s: float = 0.0
    analysis_time_s: float = 0.0


class LogicModule:
    """Schema registry; CAPre hooks the registration process here."""

    def __init__(self):
        self.registered: dict[str, RegisteredApp] = {}

    def register(
        self, app: lang.Application, policy: str = INCLUDE_BRANCH_DEPENDENT
    ) -> RegisteredApp:
        t0 = time.perf_counter()
        lower_application(app)  # the "compilation" (Wala IR generation)
        t1 = time.perf_counter()
        report = analyze_application(app, policy=policy)
        prefetch = generate_all(report)
        t2 = time.perf_counter()
        reg = RegisteredApp(
            app=app,
            report=report,
            prefetch_methods=prefetch,
            lowering_time_s=t1 - t0,
            analysis_time_s=t2 - t1,
        )
        self.registered[app.name] = reg
        return reg


#: how predicted oids become prefetch work: "batch" groups a prediction by
#: owning Data Service and submits one deduped, need-ordered batch task per
#: service (the default); "per-oid" is the historical one-task-per-object
#: dispatch, kept for A/B sweeps (``bench_predictors --dispatch``)
DISPATCH_MODES = ("batch", "per-oid")


@dataclass
class SessionConfig:
    mode: Optional[str] = None  # None or any repro.predict registry name
    rop_depth: int = 1
    parallel_workers: int = 8
    dispatch: str = "batch"  # see DISPATCH_MODES
    # trace-mined predictors (markov-miner / hybrid)
    markov_order: int = 2
    markov_confidence: float = 0.25
    markov_table_capacity: int = 65536
    markov_fanout: int = 8
    markov_chain: int = 4
    warm_trace: Optional[list] = None  # recorded ObjectStore.trace to mine
    # static-optimizer signals (core.opt annotations on the hints):
    # rfo=False ignores read-for-ownership marks (prefetches never
    # dirty-allocate — the A/B control for the write-path experiment);
    # max_outstanding > 0 arms the runtime's admission control, shedding
    # batches below admission_threshold priority once that many tasks are
    # outstanding
    rfo: bool = True
    max_outstanding: int = 0
    admission_threshold: float = 0.0
    # observability label: spans and registry sources this session creates
    # carry it (the per-tenant label scheme the future loadgen item will
    # drive; see DESIGN.md section 3.7)
    session_label: str = ""


# Process-monotonic default session labels.  The old scheme,
# ``id(self) & 0xFFFF``, collides trivially: CPython reuses freed object
# addresses, so open/close loops hand successive sessions the *same* label
# and their registry sources silently overwrite each other.
_session_ids = itertools.count(1)


class Session:
    def __init__(self, store: ObjectStore, reg: RegisteredApp, config: SessionConfig = None):
        self.store = store
        self.reg = reg
        self.app = reg.app
        self.config = config or SessionConfig()
        if self.config.dispatch not in DISPATCH_MODES:
            raise ValueError(
                f"unknown dispatch mode {self.config.dispatch!r}; "
                f"expected one of {DISPATCH_MODES}"
            )
        self.runtime = PrefetchRuntime(
            parallel_workers=self.config.parallel_workers,
            max_outstanding=self.config.max_outstanding,
            admission_threshold=self.config.admission_threshold,
        )
        # the store drains registered runtimes in reset_runtime_state so
        # straggler prefetch tasks cannot leak across benchmark repetitions
        store.register_runtime(self.runtime)
        # wire this session into the store's observability context (if one
        # is attached): its runtime queue depths become a registry source,
        # and spans opened while it runs carry its label
        self.label = self.config.session_label or f"s{next(_session_ids):04d}"
        # Spans are attributed per-call: the label rides every
        # prefetch/demand recording through the dispatch path (predictor ->
        # store.prefetch_* -> Tracer), never by mutating shared tracer
        # state.  Two concurrent labeled sessions therefore get
        # correctly-interleaved attribution, and close() has nothing
        # global to restore.
        self._tenant_stall_hist = None
        if store.obs is not None:
            store.obs.registry.register_source(
                f"runtime/{self.label}", self.runtime.stats
            )
            if self.config.session_label:
                # pre-resolved per-tenant stall histogram (hot path records
                # directly; only explicitly-labeled sessions get one so
                # anonymous open/close churn can't grow the registry)
                self._tenant_stall_hist = store.obs.registry.histogram(
                    "tenant_stall_s", tenant=self.label
                )
        # Save whatever listeners are already installed (another session's
        # monitoring) instead of clobbering them: a predictor bound below
        # may overwrite them, and close() puts the saved ones back.  A
        # mode=None session leaves the store's hooks entirely alone.
        self._saved_listeners = (store.miss_listener, store.access_listener)
        self.predictor = None
        if self.config.mode is not None:
            from repro import predict

            self.predictor = predict.make_pos_predictor(self.config.mode, config=self.config)
            self.predictor.bind(self)

    # -- injected prefetch scheduling (the paper's Listing 5 hook) -----------

    def on_method_entry(self, method_key: str, this_oid: int) -> None:
        if self.store.trace is not None:
            self.store.trace_method_entry(method_key, this_oid)
        if self.predictor is not None:
            self.predictor.on_method_entry(method_key, this_oid)

    # -- execution ---------------------------------------------------------------

    def execute(self, root_oid: int, method: str, *args):
        interp = Interpreter(self)
        return interp.execute(root_oid, method, args)

    def drain(self, timeout: float = 60.0) -> bool:
        return self.runtime.drain(timeout)

    def close(self) -> None:
        if self.predictor is not None:
            # removes only the listeners this session's predictor installed
            self.predictor.unbind()
        for attr, saved in zip(("miss_listener", "access_listener"), self._saved_listeners):
            if saved is None or getattr(self.store, attr) is not None:
                continue
            # never resurrect a hook whose predictor has since unbound
            # (sessions closed out of LIFO order): a dead miner's listener
            # would silently keep charging monitoring on every access
            owner = getattr(saved, "predictor", None)
            if owner is None or owner.session is not None:
                setattr(self.store, attr, saved)
        self.runtime.shutdown()
        self.store.unregister_runtime(self.runtime)
        if self.store.obs is not None:
            self.store.obs.registry.unregister_source(f"runtime/{self.label}")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class POSClient:
    """Convenience facade: one store + one Logic Module."""

    def __init__(self, n_services: int = 4, latency=None, cache_capacity: int = 0,
                 cache_policy: str = "lru", shared_budget: bool = False,
                 placement: str = "round-robin", replication: int = 1,
                 write_quorum: int = 1, hedge: bool = False,
                 hedge_delay: Optional[float] = None):
        from .latency import ZERO

        self.store = ObjectStore(
            n_services=n_services, latency=latency or ZERO, cache_capacity=cache_capacity,
            cache_policy=cache_policy, shared_budget=shared_budget,
            placement=placement, replication=replication,
            write_quorum=write_quorum, hedge=hedge, hedge_delay=hedge_delay,
        )
        self.logic_module = LogicModule()

    def register(self, app: lang.Application, policy: str = INCLUDE_BRANCH_DEPENDENT) -> RegisteredApp:
        return self.logic_module.register(app, policy)

    def session(self, app_name: str, mode: Optional[str] = None, rop_depth: int = 1,
                parallel_workers: int = 8, **overrides) -> Session:
        reg = self.logic_module.registered[app_name]
        cfg = SessionConfig(mode=mode, rop_depth=rop_depth,
                            parallel_workers=parallel_workers, **overrides)
        return Session(self.store, reg, cfg)
