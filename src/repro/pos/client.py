"""dataClay-like client & Logic Module (paper section 6).

Application classes are registered with the Logic Module; CAPre intercepts
the registration, runs the static analysis, and generates + injects the
prefetching methods.  A ``Session`` then executes registered methods against
the store under one of three prefetching modes:

  * ``None``      — no prefetching (the paper's baseline),
  * ``"capre"``   — hint-driven prefetching (this paper),
  * ``"rop"``     — Referenced-Objects Predictor at a configurable fetch
                    depth: every application-path cache miss eagerly schedules
                    the object's referenced single associations (never
                    collections) up to ``rop_depth`` levels.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core import lang
from repro.core.hints import AnalysisReport, analyze_application
from repro.core.injection import generate_all
from repro.core.lower import lower_application
from repro.core.rop import rop_referenced_fields
from repro.core.type_graph import INCLUDE_BRANCH_DEPENDENT

from .executor import PrefetchRuntime
from .interp import Interpreter
from .store import ObjectStore


@dataclass
class RegisteredApp:
    app: lang.Application
    report: AnalysisReport
    prefetch_methods: dict[str, object]
    lowering_time_s: float = 0.0
    analysis_time_s: float = 0.0


class LogicModule:
    """Schema registry; CAPre hooks the registration process here."""

    def __init__(self):
        self.registered: dict[str, RegisteredApp] = {}

    def register(
        self, app: lang.Application, policy: str = INCLUDE_BRANCH_DEPENDENT
    ) -> RegisteredApp:
        t0 = time.perf_counter()
        lower_application(app)  # the "compilation" (Wala IR generation)
        t1 = time.perf_counter()
        report = analyze_application(app, policy=policy)
        prefetch = generate_all(report)
        t2 = time.perf_counter()
        reg = RegisteredApp(
            app=app,
            report=report,
            prefetch_methods=prefetch,
            lowering_time_s=t1 - t0,
            analysis_time_s=t2 - t1,
        )
        self.registered[app.name] = reg
        return reg


@dataclass
class SessionConfig:
    mode: Optional[str] = None  # None | "capre" | "rop"
    rop_depth: int = 1
    parallel_workers: int = 8


class Session:
    def __init__(self, store: ObjectStore, reg: RegisteredApp, config: SessionConfig = None):
        self.store = store
        self.reg = reg
        self.app = reg.app
        self.config = config or SessionConfig()
        self.runtime = PrefetchRuntime(parallel_workers=self.config.parallel_workers)
        self._rop_fields: dict[str, list[tuple[str, str]]] = {}
        self._rop_issued: set[int] = set()
        if self.config.mode == "rop":
            for cls in self.app.classes:
                self._rop_fields[cls] = rop_referenced_fields(self.app, cls)
            store_self = self

            def _on_miss(oid: int) -> None:
                store_self._rop_trigger(oid)

            self.store.miss_listener = _on_miss
        else:
            self.store.miss_listener = None

    # -- injected prefetch scheduling (CAPre) ---------------------------------

    def on_method_entry(self, method_key: str, this_oid: int) -> None:
        if self.config.mode != "capre":
            return
        fn = self.reg.prefetch_methods.get(method_key)
        if fn is None:
            return
        self.runtime.schedule(lambda: fn(self.store, self.runtime, this_oid))

    # -- ROP eager fetch -------------------------------------------------------

    def _rop_trigger(self, oid: int) -> None:
        if oid in self._rop_issued:
            return
        self._rop_issued.add(oid)
        depth = self.config.rop_depth
        store = self.store

        def bfs(root_oid: int) -> None:
            frontier = [root_oid]
            for _ in range(depth):
                nxt: list[int] = []
                for o in frontier:
                    rec = store.record(o)
                    for fld, _target in self._rop_fields.get(rec.cls, ()):
                        ref = rec.fields.get(fld)
                        if ref is None:
                            continue
                        store.prefetch_access(ref)
                        nxt.append(ref)
                frontier = nxt
                if not frontier:
                    break

        self.runtime.fan_out(bfs, [oid])

    # -- execution ---------------------------------------------------------------

    def execute(self, root_oid: int, method: str, *args):
        interp = Interpreter(self)
        return interp.execute(root_oid, method, args)

    def drain(self, timeout: float = 60.0) -> bool:
        return self.runtime.drain(timeout)

    def close(self) -> None:
        self.store.miss_listener = None
        self.runtime.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


class POSClient:
    """Convenience facade: one store + one Logic Module."""

    def __init__(self, n_services: int = 4, latency=None):
        from .latency import ZERO

        self.store = ObjectStore(n_services=n_services, latency=latency or ZERO)
        self.logic_module = LogicModule()

    def register(self, app: lang.Application, policy: str = INCLUDE_BRANCH_DEPENDENT) -> RegisteredApp:
        return self.logic_module.register(app, policy)

    def session(self, app_name: str, mode: Optional[str] = None, rop_depth: int = 1, parallel_workers: int = 8) -> Session:
        reg = self.logic_module.registered[app_name]
        return Session(self.store, reg, SessionConfig(mode=mode, rop_depth=rop_depth, parallel_workers=parallel_workers))
