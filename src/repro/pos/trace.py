"""Versioned trace-event schema for ``ObjectStore.trace`` (schema v2).

The v1 trace was a bare list of oids appended by ``app_access`` — reads
only, so the replay engine could not charge the write path and mutating
workloads (``setAllTransCustomers``) were scored as if they never wrote.
v2 records typed events:

  * ``access``       — an application-path read navigation (``app_access``);
  * ``write``        — an application-path field update (``app_write``);
  * ``method_entry`` — entry into a registered method (the paper's injected
    scheduling point, recorded by ``Session.on_method_entry``).

Back-compat is explicit, not implicit: consumers that want the plain
demand-oid sequence (the markov miner's training input, accuracy sets)
call :func:`trace_oids`, and replay engines normalize arbitrary trace
shapes — bare oids, legacy ``("enter", key, oid)`` tuples, or
:class:`TraceEvent` records — through :func:`as_events`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional

#: bumped whenever the recorded event vocabulary changes
TRACE_SCHEMA_VERSION = 2

ACCESS = "access"
WRITE = "write"
METHOD_ENTRY = "method_entry"

#: the demand-path kinds — events where the application touches an object
#: (and a predictor could have prefetched it)
DEMAND_KINDS = (ACCESS, WRITE)

# legacy tuple spelling used by the pre-v2 offline recorder, plus the
# canonical kind names so serialized events (``TraceEvent.to_tuple``)
# round-trip through ``as_events``
_LEGACY_KINDS = {
    "enter": METHOD_ENTRY,
    METHOD_ENTRY: METHOD_ENTRY,
    ACCESS: ACCESS,
    WRITE: WRITE,
}


@dataclass(frozen=True)
class TraceEvent:
    kind: str  # ACCESS | WRITE | METHOD_ENTRY
    oid: int
    method_key: Optional[str] = None  # METHOD_ENTRY only

    @property
    def is_demand(self) -> bool:
        return self.kind in DEMAND_KINDS

    def to_tuple(self) -> tuple:
        """Serialize to the plain-tuple wire form (JSON-friendly: strings
        and ints only).  ``as_events`` accepts the result, so a trace can be
        dumped to disk and replayed: ``as_events(ev.to_tuple() for ev in
        trace)`` round-trips exactly."""
        if self.kind == METHOD_ENTRY:
            return (self.kind, self.method_key, self.oid)
        return (self.kind, self.oid)


def access_event(oid: int) -> TraceEvent:
    return TraceEvent(ACCESS, oid)


def write_event(oid: int) -> TraceEvent:
    return TraceEvent(WRITE, oid)


def method_entry_event(method_key: str, oid: int) -> TraceEvent:
    return TraceEvent(METHOD_ENTRY, oid, method_key)


def _coerce(item) -> TraceEvent:
    if isinstance(item, TraceEvent):
        return item
    if isinstance(item, int):  # v1 bare-oid trace: every entry was a read
        return TraceEvent(ACCESS, item)
    if isinstance(item, tuple) and item and item[0] in _LEGACY_KINDS:
        kind = _LEGACY_KINDS[item[0]]
        if kind == METHOD_ENTRY:
            _, key, oid = item
            return TraceEvent(METHOD_ENTRY, oid, key)
        return TraceEvent(kind, item[1])
    raise TypeError(f"unrecognized trace entry {item!r}")


def as_events(trace: Iterable) -> list[TraceEvent]:
    """Normalize any supported trace shape to ``TraceEvent`` records."""
    return [_coerce(item) for item in trace]


def trace_oids(trace: Iterable, kinds: tuple[str, ...] = DEMAND_KINDS) -> list[int]:
    """The plain oid sequence of the demand-path events, in order — what
    v1 consumers (``Predictor.warm``, accuracy sets) operated on.  Accepts
    bare-oid lists unchanged, so pre-v2 recorded traces keep working."""
    return [ev.oid for ev in as_events(trace) if ev.kind in kinds]
