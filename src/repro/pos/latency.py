"""Latency/cost model of the simulated dataClay deployment.

The paper's cluster: 5 nodes, 10GbE, 5400rpm HDDs — data access is dominated
by (a) pulling an object from the Data Service's disk into its memory and
(b) redirecting execution between Data Services over the network.  We model
both with real ``time.sleep`` so that genuinely concurrent prefetch threads
(the paper uses JVM thread pools + parallel streams) produce genuine
wall-clock improvements, and provide a zero-latency mode so unit tests are
fast and fully deterministic.

All latencies are in seconds.  Sub-50µs latencies are treated as free
(Python's sleep granularity would otherwise distort them).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

_MIN_SLEEP = 50e-6


@dataclass(frozen=True)
class LatencyModel:
    disk_load: float = 300e-6  # DS disk -> DS memory (the cost prefetch hides)
    remote_hop: float = 120e-6  # execution redirection between Data Services
    write_back: float = 350e-6  # storing an updated object
    think: float = 100e-6  # per-object application processing time
    parallel_per_ds: int = 4  # concurrent disk loads per DS (4-core nodes)
    # per-task submission cost of the prefetch executor — only consulted by
    # the virtual clock (the live store pays it for real in Python executor
    # overhead): each dispatch serializes on the submitting side, so a
    # per-oid dispatcher issues its i-th load ~i*dispatch_overhead late,
    # while a batched dispatcher pays it once per Data-Service batch
    dispatch_overhead: float = 0.0

    def sleep(self, seconds: float) -> None:
        if seconds >= _MIN_SLEEP:
            time.sleep(seconds)

    @property
    def is_zero(self) -> bool:
        return self.disk_load == 0 and self.remote_hop == 0 and self.write_back == 0

    def scaled(self, scale: float) -> "LatencyModel":
        """A copy with every *time* constant multiplied by ``scale`` (slot
        counts untouched) — how the fitted wall-vs-virtual calibration
        factors (``predict.calibration``) are applied to a replay model."""
        from dataclasses import replace

        return replace(
            self,
            disk_load=self.disk_load * scale,
            remote_hop=self.remote_hop * scale,
            write_back=self.write_back * scale,
            think=self.think * scale,
            dispatch_overhead=self.dispatch_overhead * scale,
        )


ZERO = LatencyModel(disk_load=0.0, remote_hop=0.0, write_back=0.0, think=0.0)
DEFAULT = LatencyModel()


def now() -> float:
    return time.perf_counter()


# ---------------------------------------------------------------------------
# pure cost arithmetic (no sleeping) — the virtual-clock side of the model
# ---------------------------------------------------------------------------


class VirtualDisk:
    """Pure-arithmetic model of one Data Service's disk under the same
    constants ``LatencyModel.sleep`` burns for real: ``parallel_per_ds``
    service slots, each load occupying a slot for ``disk_load`` seconds.

    ``predict.evaluate`` replays recorded traces against this instead of
    sleeping, so a predicted object gets a deterministic *ready-at* time
    (including queueing behind other loads on the same service — where
    over-eager predictors congest their own prefetches)."""

    def __init__(self, latency: LatencyModel):
        self.latency = latency
        self._slots = [0.0] * max(1, latency.parallel_per_ds)
        self.loads = 0
        self.write_backs = 0
        self.busy_seconds = 0.0
        self.last_slot = 0  # slot index taken by the most recent _occupy

    def _occupy(self, t: float, seconds: float) -> tuple[float, float]:
        i = min(range(len(self._slots)), key=self._slots.__getitem__)
        start = max(t, self._slots[i])
        done = start + seconds
        self._slots[i] = done
        self.busy_seconds += seconds
        self.last_slot = i
        return start, done

    def schedule(self, t: float) -> tuple[float, float]:
        """Schedule one disk load requested at virtual time ``t``; returns
        ``(start, done)``.  The load takes the earliest-free slot: it starts
        at ``max(t, slot_free)`` and completes ``disk_load`` later."""
        self.loads += 1
        return self._occupy(t, self.latency.disk_load)

    def schedule_batch(self, t: float, n: int) -> list[tuple[float, float]]:
        """Schedule ``n`` disk loads, all requested at virtual time ``t`` —
        one batched prefetch request pipelining through the service's slots.
        Identical slot arithmetic to ``n`` separate ``schedule`` calls; the
        batching win is modeled at the *dispatch* layer (one
        ``dispatch_overhead`` charge per batch instead of per oid)."""
        return [self.schedule(t) for _ in range(n)]

    def schedule_write_back(self, t: float) -> tuple[float, float]:
        """Schedule one write-back (dirty-eviction flush) requested at
        virtual time ``t``.  Write-backs occupy the *same* service slots as
        loads for ``write_back`` seconds — the flush itself is off the
        application's critical path, but it delays whatever loads queue
        behind it, which is how the replay charges the write path."""
        self.write_backs += 1
        return self._occupy(t, self.latency.write_back)


# Constants used by the offline replay engine: the paper's HDD regime, where
# per-object disk latency dwarfs per-object compute (5400rpm: milliseconds vs
# sub-millisecond think).  An access-ahead miner can only buy ``think`` worth
# of lead per step, far short of one disk load — method-level lead (CAPre's
# injected scheduling point) is what arrives early enough.  Aggregate disk
# bandwidth (n_services x parallel_per_ds) still exceeds the application's
# consumption rate, so a predictor with enough lead CAN fully hide the disk:
# timeliness, not bandwidth, is what the replay measures.
REPLAY = LatencyModel(
    disk_load=2e-3, remote_hop=120e-6, write_back=4e-3, think=250e-6, parallel_per_ds=2,
    dispatch_overhead=50e-6,
)
