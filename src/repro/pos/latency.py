"""Latency/cost model of the simulated dataClay deployment.

The paper's cluster: 5 nodes, 10GbE, 5400rpm HDDs — data access is dominated
by (a) pulling an object from the Data Service's disk into its memory and
(b) redirecting execution between Data Services over the network.  We model
both with real ``time.sleep`` so that genuinely concurrent prefetch threads
(the paper uses JVM thread pools + parallel streams) produce genuine
wall-clock improvements, and provide a zero-latency mode so unit tests are
fast and fully deterministic.

All latencies are in seconds.  Sub-50µs latencies are treated as free
(Python's sleep granularity would otherwise distort them).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

_MIN_SLEEP = 50e-6


@dataclass(frozen=True)
class LatencyModel:
    disk_load: float = 300e-6  # DS disk -> DS memory (the cost prefetch hides)
    remote_hop: float = 120e-6  # execution redirection between Data Services
    write_back: float = 350e-6  # storing an updated object
    think: float = 100e-6  # per-object application processing time
    parallel_per_ds: int = 4  # concurrent disk loads per DS (4-core nodes)
    # per-task submission cost of the prefetch executor — only consulted by
    # the virtual clock (the live store pays it for real in Python executor
    # overhead): each dispatch serializes on the submitting side, so a
    # per-oid dispatcher issues its i-th load ~i*dispatch_overhead late,
    # while a batched dispatcher pays it once per Data-Service batch
    dispatch_overhead: float = 0.0
    # per-service disk-time multipliers (straggler regimes): service i's
    # disk_load and write_back scale by service_scales[i]; services past the
    # tuple's end (or an empty tuple — the default) run at 1.0.  This is how
    # a slow/degraded Data Service enters the cost model without touching
    # the cluster-wide constants.
    service_scales: tuple[float, ...] = ()
    # what a demand access pays to notice a dead service and re-route to a
    # replica (failover detection + retry); only charged on actual failover
    failover_detect: float = 0.0

    def sleep(self, seconds: float) -> None:
        if seconds >= _MIN_SLEEP:
            time.sleep(seconds)

    def scale_for(self, ds_id: int) -> float:
        if 0 <= ds_id < len(self.service_scales):
            return self.service_scales[ds_id]
        return 1.0

    def disk_load_for(self, ds_id: int) -> float:
        return self.disk_load * self.scale_for(ds_id)

    def write_back_for(self, ds_id: int) -> float:
        return self.write_back * self.scale_for(ds_id)

    def with_stragglers(self, scales: dict[int, float]) -> "LatencyModel":
        """A copy where service ``i`` runs ``scales[i]`` times slower on
        disk (1.0 elsewhere) — the per-service slow/straggler regime."""
        from dataclasses import replace

        width = max(scales) + 1 if scales else 0
        return replace(
            self,
            service_scales=tuple(scales.get(i, 1.0) for i in range(width)),
        )

    @property
    def is_zero(self) -> bool:
        return self.disk_load == 0 and self.remote_hop == 0 and self.write_back == 0

    def scaled(self, scale: float) -> "LatencyModel":
        """A copy with every *time* constant multiplied by ``scale`` (slot
        counts and the per-service straggler multipliers untouched) — how
        the fitted wall-vs-virtual calibration factors
        (``predict.calibration``) are applied to a replay model."""
        from dataclasses import replace

        return replace(
            self,
            disk_load=self.disk_load * scale,
            remote_hop=self.remote_hop * scale,
            write_back=self.write_back * scale,
            think=self.think * scale,
            dispatch_overhead=self.dispatch_overhead * scale,
            failover_detect=self.failover_detect * scale,
        )


ZERO = LatencyModel(disk_load=0.0, remote_hop=0.0, write_back=0.0, think=0.0)
DEFAULT = LatencyModel()


def now() -> float:
    return time.perf_counter()


# ---------------------------------------------------------------------------
# pure cost arithmetic (no sleeping) — the virtual-clock side of the model
# ---------------------------------------------------------------------------


class VirtualDisk:
    """Pure-arithmetic model of one Data Service's disk under the same
    constants ``LatencyModel.sleep`` burns for real: ``parallel_per_ds``
    service slots, each load occupying a slot for ``disk_load`` seconds.

    ``predict.evaluate`` replays recorded traces against this instead of
    sleeping, so a predicted object gets a deterministic *ready-at* time
    (including queueing behind other loads on the same service — where
    over-eager predictors congest their own prefetches)."""

    def __init__(self, latency: LatencyModel, scale: float = 1.0):
        self.latency = latency
        # per-service straggler multiplier (1.0 = nominal): scales this
        # disk's service times without touching the shared LatencyModel
        self._disk_load = latency.disk_load * scale
        self._write_back = latency.write_back * scale
        self._slots = [0.0] * max(1, latency.parallel_per_ds)
        self.loads = 0
        self.write_backs = 0
        self.busy_seconds = 0.0
        self.last_slot = 0  # slot index taken by the most recent _occupy

    def _occupy(self, t: float, seconds: float) -> tuple[float, float]:
        i = min(range(len(self._slots)), key=self._slots.__getitem__)
        start = max(t, self._slots[i])
        done = start + seconds
        self._slots[i] = done
        self.busy_seconds += seconds
        self.last_slot = i
        return start, done

    def schedule(self, t: float) -> tuple[float, float]:
        """Schedule one disk load requested at virtual time ``t``; returns
        ``(start, done)``.  The load takes the earliest-free slot: it starts
        at ``max(t, slot_free)`` and completes ``disk_load`` later."""
        self.loads += 1
        return self._occupy(t, self._disk_load)

    def schedule_batch(self, t: float, n: int) -> list[tuple[float, float]]:
        """Schedule ``n`` disk loads, all requested at virtual time ``t`` —
        one batched prefetch request pipelining through the service's slots.
        Identical slot arithmetic to ``n`` separate ``schedule`` calls; the
        batching win is modeled at the *dispatch* layer (one
        ``dispatch_overhead`` charge per batch instead of per oid)."""
        return [self.schedule(t) for _ in range(n)]

    def schedule_write_back(self, t: float) -> tuple[float, float]:
        """Schedule one write-back (dirty-eviction flush) requested at
        virtual time ``t``.  Write-backs occupy the *same* service slots as
        loads for ``write_back`` seconds — the flush itself is off the
        application's critical path, but it delays whatever loads queue
        behind it, which is how the replay charges the write path."""
        self.write_backs += 1
        return self._occupy(t, self._write_back)


# Constants used by the offline replay engine: the paper's HDD regime, where
# per-object disk latency dwarfs per-object compute (5400rpm: milliseconds vs
# sub-millisecond think).  An access-ahead miner can only buy ``think`` worth
# of lead per step, far short of one disk load — method-level lead (CAPre's
# injected scheduling point) is what arrives early enough.  Aggregate disk
# bandwidth (n_services x parallel_per_ds) still exceeds the application's
# consumption rate, so a predictor with enough lead CAN fully hide the disk:
# timeliness, not bandwidth, is what the replay measures.
REPLAY = LatencyModel(
    disk_load=2e-3, remote_hop=120e-6, write_back=4e-3, think=250e-6, parallel_per_ds=2,
    dispatch_overhead=50e-6,
)


# ---------------------------------------------------------------------------
# failure scenarios — the regimes the replay engine and bench_placement sweep
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FailureScenario:
    """One failure regime for a replay/bench run.

    ``straggler`` is ``((ds_id, disk_scale), ...)``: those services' disk
    times multiply by the scale (the slow-service regime).  ``crash_service``
    (with ``crash_at`` in virtual seconds) kills one service mid-run: its
    cache and in-flight loads are lost, claimed-but-unlanded prefetches
    re-dispatch to a surviving replica after ``failover_delay``, and demand
    reads route around the corpse (replication >= 2 required — with a single
    replica the data is simply gone and the replay raises)."""

    name: str = "no-fault"
    straggler: tuple[tuple[int, float], ...] = ()
    crash_service: Optional[int] = None
    crash_at: float = float("inf")
    failover_delay: float = 2e-3
    # network partition: ``partition`` is a tuple of service-id groups; group
    # 0 is the client-side majority (unlisted services implicitly belong to
    # it).  Between ``partition_at`` and ``heal_at`` every service outside
    # group 0 is unreachable: demand/prefetch traffic to it fails like a
    # crash, but its state survives — at ``heal_at`` it readmits with a warm
    # cache and anti-entropy resyncs the dirty lines it missed.
    partition: tuple[tuple[int, ...], ...] = ()
    partition_at: float = float("inf")
    heal_at: float = float("inf")
    # crash+revive: a service crashed at ``crash_at`` comes back (cold cache)
    # at ``revive_at`` and readmits into routing
    revive_at: float = float("inf")
    # hedged reads: demand misses issue to a second replica after a
    # hedge delay and take the first response.  ``hedge_delay`` 0.0 means
    # "derive" (the store/replay derives it from the latency model's p99).
    hedge: bool = False
    hedge_delay: float = 0.0

    @property
    def is_fault(self) -> bool:
        return (bool(self.straggler) or self.crash_service is not None
                or bool(self.partition))

    def straggler_scales(self) -> dict[int, float]:
        return dict(self.straggler)

    def cut_services(self) -> set[int]:
        """Services unreachable from the client side while partitioned
        (everything outside group 0)."""
        if not self.partition:
            return set()
        return {ds for grp in self.partition[1:] for ds in grp}


#: scenario vocabulary bench_placement / evaluate sweep by name
SCENARIO_NAMES = ("no-fault", "straggler", "crash", "partition",
                  "crash+revive", "straggler+hedge")


def make_scenario(name: str, end_t: float = 0.0, ds_id: int = 0,
                  straggler_scale: float = 8.0,
                  crash_frac: float = 0.25) -> FailureScenario:
    """Resolve a named regime: ``straggler`` makes ``ds_id`` run
    ``straggler_scale`` times slower on disk; ``crash`` kills ``ds_id`` at
    ``crash_frac`` of the no-fault baseline's end time ``end_t`` (mid-run,
    so in-flight prefetch batches are caught on the dead service);
    ``partition`` isolates ``ds_id`` from the client-side majority between
    25% and 70% of ``end_t`` (heal readmits it warm and resyncs missed
    writes); ``crash+revive`` kills ``ds_id`` at 25% and revives it cold at
    60%; ``straggler+hedge`` is the straggler regime with hedged demand
    reads armed."""
    if name == "no-fault":
        return FailureScenario()
    if name == "straggler":
        return FailureScenario(name=name, straggler=((ds_id, straggler_scale),))
    if name == "crash":
        return FailureScenario(name=name, crash_service=ds_id,
                               crash_at=end_t * crash_frac)
    if name == "partition":
        return FailureScenario(name=name, partition=((), (ds_id,)),
                               partition_at=end_t * crash_frac,
                               heal_at=end_t * 0.70)
    if name == "crash+revive":
        return FailureScenario(name=name, crash_service=ds_id,
                               crash_at=end_t * crash_frac,
                               revive_at=end_t * 0.60)
    if name == "straggler+hedge":
        return FailureScenario(name=name,
                               straggler=((ds_id, straggler_scale),),
                               hedge=True)
    raise KeyError(f"unknown failure scenario {name!r}; expected one of {SCENARIO_NAMES}")
