"""Latency/cost model of the simulated dataClay deployment.

The paper's cluster: 5 nodes, 10GbE, 5400rpm HDDs — data access is dominated
by (a) pulling an object from the Data Service's disk into its memory and
(b) redirecting execution between Data Services over the network.  We model
both with real ``time.sleep`` so that genuinely concurrent prefetch threads
(the paper uses JVM thread pools + parallel streams) produce genuine
wall-clock improvements, and provide a zero-latency mode so unit tests are
fast and fully deterministic.

All latencies are in seconds.  Sub-50µs latencies are treated as free
(Python's sleep granularity would otherwise distort them).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

_MIN_SLEEP = 50e-6


@dataclass(frozen=True)
class LatencyModel:
    disk_load: float = 300e-6  # DS disk -> DS memory (the cost prefetch hides)
    remote_hop: float = 120e-6  # execution redirection between Data Services
    write_back: float = 350e-6  # storing an updated object
    think: float = 100e-6  # per-object application processing time
    parallel_per_ds: int = 4  # concurrent disk loads per DS (4-core nodes)

    def sleep(self, seconds: float) -> None:
        if seconds >= _MIN_SLEEP:
            time.sleep(seconds)

    @property
    def is_zero(self) -> bool:
        return self.disk_load == 0 and self.remote_hop == 0 and self.write_back == 0


ZERO = LatencyModel(disk_load=0.0, remote_hop=0.0, write_back=0.0, think=0.0)
DEFAULT = LatencyModel()


def now() -> float:
    return time.perf_counter()
