"""Pluggable object-placement policies for the distributed store.

PR 4 made eviction a policy; this module does the same for *topology*.
``ObjectStore.put`` delegates the "which Data Service owns this object"
decision (plus its replica set) to a :class:`PlacementPolicy`:

  * ``round-robin`` — the dataClay default this repo has always modeled
    ("stored collections are automatically distributed among the available
    Data Services"): one global counter, one service per put.  Byte-exact
    with the historical inline ``next(count) % n`` so the committed
    baseline.csv replays identically under it.
  * ``consistent-hash`` — a virtual-node hash ring (sha1, 64 vnodes per
    service).  Placement becomes a pure function of the oid: no shared
    counter, minimal movement when the service count changes — the
    standard distributed-KV layout (Palpatine's substrate).
  * ``locality`` — co-locates *hint-tree subtrees*: a put may carry a
    ``group`` key (the apps tag each collection element's subtree — a bank
    transaction with its account/customer chain, an oo7 composite part
    with its atomic parts and connections); every object of one group
    lands on one service, and the groups themselves round-robin for
    balance.  One ``prefetch_batch`` of one subtree then becomes ONE
    service batch instead of fanning out across the cluster — trading
    cross-service parallelism for dispatch locality (measured by
    ``benchmarks/bench_placement.py``).

Replication: every policy returns a replica *set* (primary first) of
``replication`` distinct services; the spread is primary + successors on
the service ring, so two replicas never share a service.

All policies are deterministic: same put sequence (and group keys) =>
same placement, which is what lets the virtual-clock replay re-place a
recorded store (``ObjectStore.rebuild_placement``) and sweep placement
policies without re-recording traces.
"""

from __future__ import annotations

import bisect
import hashlib
import itertools
from typing import Optional


def spread(primary: int, n_services: int, replication: int) -> tuple[int, ...]:
    """Replica set for ``primary``: itself plus the next ``replication - 1``
    distinct services on the ring (primary first — read routing prefers
    earlier replicas on ties)."""
    r = max(1, min(replication, n_services))
    return tuple((primary + k) % n_services for k in range(r))


class PlacementPolicy:
    """Host contract: ``place`` is called once per unpinned ``put`` in
    creation order and returns the object's replica set (primary first).
    State (counters, group maps) must be deterministic in the call
    sequence — ``reset`` rewinds it for a re-placement pass."""

    name = "base"

    def __init__(self, n_services: int, replication: int = 1):
        self.n_services = n_services
        self.replication = max(1, min(replication, n_services))

    def place(self, oid: int, cls: str, group: Optional[str] = None) -> tuple[int, ...]:
        raise NotImplementedError

    def spread(self, primary: int) -> tuple[int, ...]:
        return spread(primary, self.n_services, self.replication)

    def reset(self) -> None:
        pass


class RoundRobinPlacement(PlacementPolicy):
    """One shared counter, one service per put — the dataClay distribution
    the paper's parallel prefetching exploits.  Group keys are ignored."""

    name = "round-robin"

    def __init__(self, n_services: int, replication: int = 1):
        super().__init__(n_services, replication)
        self._rr = itertools.count()

    def place(self, oid: int, cls: str, group: Optional[str] = None) -> tuple[int, ...]:
        return self.spread(next(self._rr) % self.n_services)

    def reset(self) -> None:
        self._rr = itertools.count()


def _token(key: str) -> int:
    return int.from_bytes(hashlib.sha1(key.encode()).digest()[:8], "big")


class ConsistentHashPlacement(PlacementPolicy):
    """Virtual-node hash ring: placement is a pure function of the oid
    (stateless between puts).  Replicas walk the ring clockwise to the next
    distinct services, the classic Dynamo/Cassandra layout."""

    name = "consistent-hash"
    vnodes = 64

    def __init__(self, n_services: int, replication: int = 1):
        super().__init__(n_services, replication)
        ring = sorted(
            (_token(f"ds{i}#v{v}"), i)
            for i in range(n_services)
            for v in range(self.vnodes)
        )
        self._tokens = [t for t, _ in ring]
        self._owners = [i for _, i in ring]

    def place(self, oid: int, cls: str, group: Optional[str] = None) -> tuple[int, ...]:
        start = bisect.bisect_right(self._tokens, _token(f"oid{oid}")) % len(self._owners)
        reps: list[int] = []
        for k in range(len(self._owners)):
            ds = self._owners[(start + k) % len(self._owners)]
            if ds not in reps:
                reps.append(ds)
                if len(reps) == self.replication:
                    break
        return tuple(reps)


class LocalityAwarePlacement(PlacementPolicy):
    """Co-locate hint-tree subtrees: all objects sharing a ``group`` key
    land on one service (first-seen groups round-robin for balance, so the
    cluster stays level while each subtree stays whole).  Ungrouped objects
    fall back to plain round-robin on the same counter."""

    name = "locality"

    def __init__(self, n_services: int, replication: int = 1):
        super().__init__(n_services, replication)
        self._rr = itertools.count()
        self._groups: dict[str, int] = {}

    def place(self, oid: int, cls: str, group: Optional[str] = None) -> tuple[int, ...]:
        if group is None:
            primary = next(self._rr) % self.n_services
        else:
            primary = self._groups.get(group)
            if primary is None:
                primary = next(self._rr) % self.n_services
                self._groups[group] = primary
        return self.spread(primary)

    def reset(self) -> None:
        self._rr = itertools.count()
        self._groups.clear()


PLACEMENTS: dict[str, type[PlacementPolicy]] = {
    RoundRobinPlacement.name: RoundRobinPlacement,
    ConsistentHashPlacement.name: ConsistentHashPlacement,
    LocalityAwarePlacement.name: LocalityAwarePlacement,
}

DEFAULT_PLACEMENT = RoundRobinPlacement.name


def make_placement(name: str, n_services: int, replication: int = 1) -> PlacementPolicy:
    try:
        cls = PLACEMENTS[name]
    except KeyError:
        raise KeyError(
            f"unknown placement policy {name!r}; expected one of {sorted(PLACEMENTS)}"
        ) from None
    return cls(n_services, replication=replication)


def available_placements() -> list[str]:
    return sorted(PLACEMENTS)
