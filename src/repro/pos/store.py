"""The distributed object store: Data Services, object placement, caches and
the access cost accounting (paper section 6).

Semantics mirrored from dataClay:

  * objects never leave the store; execution is redirected to the Data
    Service holding the object ("dataClay does not send the objects to the
    client but rather executes the methods locally in the same Data Service
    where the object is stored");
  * each Data Service has a local memory cache over its disk; *prefetching
    loads the object where it is stored* — it removes the disk load from the
    application's critical path but not the execution redirection;
  * stored collections are automatically distributed among the available
    Data Services, which is what makes parallel prefetching profitable —
    *how* they distribute is a pluggable placement policy (``pos.placement``:
    round-robin, consistent-hash, locality-aware subtree co-location);
  * objects may be stored on ``replication`` Data Services (primary +
    ring successors); demand reads pick a replica with load-aware routing
    (prefer the replica that already holds the line, else least-queued),
    and a crashed service fails over to the survivors: demand reads
    re-route, claimed-but-unlanded prefetch batches re-dispatch.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional

from .eviction import DEFAULT_POLICY, SharedBudget, make_policy
from .latency import LatencyModel, ZERO
from .placement import DEFAULT_PLACEMENT, make_placement
from .placement import spread as placement_spread
from .trace import TraceEvent, access_event, write_event, method_entry_event


class ServiceCrashed(RuntimeError):
    """An operation landed on a crashed Data Service.  The store's demand
    path catches this, marks the service down and fails over to a replica;
    batch lanes catch it and re-dispatch their unlanded oids."""

    def __init__(self, ds_id: int):
        super().__init__(f"data service {ds_id} crashed")
        self.ds_id = ds_id


class NoReplicaAvailable(RuntimeError):
    """Every replica of an object is down — with replication factor 1 a
    single crash makes its objects unreachable (the failure replication
    exists to mask)."""

    def __init__(self, oid: int, replicas):
        super().__init__(
            f"no alive replica for oid {oid} (replicas {list(replicas)})"
        )
        self.oid = oid


class RetryExhausted(RuntimeError):
    """The demand-path failover loop gave up after its bounded retries —
    every routing attempt kept landing on dead/unreachable services.  A
    full-outage oid now fails fast instead of spinning forever."""

    def __init__(self, oid: int, attempts: int):
        super().__init__(
            f"demand load of oid {oid} exhausted {attempts} failover retries"
        )
        self.oid = oid
        self.attempts = attempts


class QuorumUnreachable(RuntimeError):
    """A replicated write could not reach its W-of-R quorum (too many
    replicas dead or across a partition) within the bounded retry budget.
    The local update stands — the write degrades to sloppy — but the caller
    is told consistency was not achieved."""

    def __init__(self, oid: int, wanted: int, got: int):
        super().__init__(
            f"write quorum for oid {oid}: wanted {wanted} replicas, "
            f"only {got} reachable"
        )
        self.oid = oid
        self.wanted = wanted
        self.got = got


@dataclass
class PersistentObject:
    oid: int
    cls: str
    fields: dict[str, Any] = field(default_factory=dict)  # refs: oid / [oid]; prims: value


class _SlotRelease:
    """Context manager releasing an already-acquired semaphore slot."""

    def __init__(self, sem: threading.Semaphore):
        self._sem = sem

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self._sem.release()
        return False


class DataService:
    def __init__(self, ds_id: int, latency: LatencyModel, cache_capacity: int = 0,
                 policy: str = DEFAULT_POLICY, budget: Optional[SharedBudget] = None):
        self.ds_id = ds_id
        self.latency = latency
        # fail-stop flag: crash() clears it; a dead service raises
        # ServiceCrashed from every load/claim so callers fail over
        self.alive = True
        self.disk: dict[int, PersistentObject] = {}
        # bounded memory cache (capacity 0 = unbounded, the paper's regime);
        # eviction order is delegated to a pluggable policy (pos.eviction) —
        # a bounded cache exposes prefetch thrashing: useless ROP prefetches
        # evict objects the application still needs.  Under a SharedBudget
        # every service draws lines from one global capacity instead, the
        # budget's policy spans all services (victims may be stolen from
        # another service's cache), and all services share one cache lock so
        # cross-service victim selection is race-free.
        self.cache_capacity = cache_capacity
        self.cache: dict[int, None] = {}
        self.budget = budget
        self.policy = budget.policy if budget is not None else make_policy(
            policy, capacity=cache_capacity
        )
        self._cache_lock = budget.lock if budget is not None else threading.Lock()
        self._slots = threading.Semaphore(max(1, latency.parallel_per_ds))
        # application threads queued for a disk slot: background prefetch
        # yields to them (see _yield_to_demand) — a hot batch lane
        # re-acquiring the slot semaphore would otherwise starve a woken
        # demand waiter indefinitely (semaphores are not FIFO-fair)
        self._demand_waiting = 0
        self._demand_clear = threading.Event()
        self._demand_clear.set()
        # request coalescing: concurrent loads of the same object share one
        # disk read — the second requester waits out the remaining latency
        self._inflight: dict[int, threading.Event] = {}
        # write-back cache state: updated-in-memory objects whose disk copy
        # is stale; flushed (paying ``latency.write_back``) on eviction and
        # on ``drop_cache``, never on the write itself
        self.dirty: set[int] = set()
        self.evictions = 0
        self.dirty_evictions = 0
        self.flushed_writes = 0
        # per-service prefetch counters (updated under this service's cache
        # lock) — the prefetch path used to charge the store-wide metrics
        # lock per oid, contending with the application thread; now each
        # service counts locally and ``ObjectStore.snapshot_metrics``
        # aggregates on read
        self.prefetch_requests = 0  # oids prefetch looked at (incl. cache hits)
        self.prefetch_loads = 0  # disk loads performed by prefetch work
        self.batch_dispatches = 0  # prefetch tasks submitted for this service
        self.dedup_suppressed = 0  # oids suppressed pre-submission (cached/in-flight/dup)
        self.demand_steals = 0  # lane-claimed oids a demand access took over
        self.rfo_prefetches = 0  # prefetch loads dirty-allocated (read-for-ownership)
        # set by the owning ObjectStore so flush/eviction events land on
        # the shared StoreMetrics too (None for a standalone DataService)
        self._owner: Optional["ObjectStore"] = None

    @property
    def _tracer(self):
        """The owning store's span tracer, if observability is attached
        (None otherwise — a standalone DataService records no spans).  The
        tracer's lock is a leaf, so calls are safe under the cache lock."""
        owner = self._owner
        obs = owner.obs if owner is not None else None
        return obs.tracer if obs is not None else None

    def _touch(self, oid: int, prefetch: bool = False) -> list[tuple["DataService", int]]:
        """Policy bump/insert + bounded-capacity eviction (callers hold the
        cache lock).  Returns the dirty ``(service, victim)`` pairs that now
        need flushing — the caller flushes *after* releasing the lock, on
        the victim's own service (which, under a shared budget, may not be
        this one)."""
        if oid in self.cache:
            self.policy.note_access(oid, prefetch=prefetch)
        else:
            self.cache[oid] = None
            if self.budget is not None:
                self.budget.note_insert(oid, self, prefetch=prefetch)
            else:
                self.policy.note_insert(oid, prefetch=prefetch)
        flushes: list[tuple[DataService, int]] = []
        if self.budget is not None:
            while self.budget.overflowed():
                holders, victim = self.budget.pick_victim()
                for vds in holders:  # every replica copy shares the line
                    vds._evict_line(victim, flushes)
        elif self.cache_capacity:
            while len(self.cache) > self.cache_capacity:
                self._evict_line(self.policy.pick_victim(), flushes)
        return flushes

    def _evict_line(self, victim: int, flushes: list[tuple["DataService", int]]) -> None:
        """Drop one resident line (policy already forgot it); queue its
        flush if dirty.  Callers hold the cache lock."""
        self.cache.pop(victim, None)
        self.evictions += 1
        tr = self._tracer
        if tr is not None:
            tr.evicted(victim)  # terminal "evicted" for an unused prefetch span
        if victim in self.dirty:
            self.dirty.discard(victim)
            self.dirty_evictions += 1
            if self._owner is not None:
                self._owner._note_dirty_eviction()
            flushes.append((self, victim))

    def _flush(self, oid: int) -> None:
        """Write a dirty object back to disk (occupies a disk slot for
        ``write_back`` seconds — the deferred cost of the write path).  On a
        crashed service the flush fails over to a live replica when one
        exists (replication > 1); otherwise the in-memory update is lost —
        counted, no longer silent."""
        if not self.alive:
            owner = self._owner
            if owner is not None and owner._flush_failover(self.ds_id, oid):
                return
            if owner is not None:
                owner._note_lost_write(self.ds_id, oid)
            return
        with self._slots:
            self.latency.sleep(self.latency.write_back_for(self.ds_id))
        self.flushed_writes += 1
        if self._owner is not None:
            self._owner._note_flush()

    def reset_counters(self) -> None:
        """Zero the per-service counters (between benchmark repetitions) —
        previously ``evictions`` survived ``reset_runtime_state`` and
        accumulated across reps, polluting every thrash-sweep row after
        the first."""
        self.evictions = 0
        self.dirty_evictions = 0
        self.flushed_writes = 0
        self.prefetch_requests = 0
        self.prefetch_loads = 0
        self.batch_dispatches = 0
        self.dedup_suppressed = 0
        self.demand_steals = 0
        self.rfo_prefetches = 0
        self.policy.protected_evictions = 0

    def is_cached(self, oid: int) -> bool:
        with self._cache_lock:
            return oid in self.cache

    def _yield_to_demand(self) -> None:
        """Background prefetch work parks until no application thread is
        waiting for one of this service's disk slots — the paper's contract
        ('the application thread is never interrupted') applied to the disk
        queue: demand loads have strict priority over prefetch loads.  The
        5s timeout is purely defensive (a stuck demand waiter must not hang
        the prefetcher forever)."""
        if self._demand_waiting:
            self._demand_clear.wait(5.0)

    def _demand_slot(self):
        """Acquire a disk slot for an application (demand) load, flagging
        the wait so background prefetch yields the queue.  Returns a
        context manager holding the slot."""
        with self._cache_lock:
            self._demand_waiting += 1
            self._demand_clear.clear()
        self._slots.acquire()
        with self._cache_lock:
            self._demand_waiting -= 1
            if self._demand_waiting == 0:
                self._demand_clear.set()
        return _SlotRelease(self._slots)

    def load_into_memory(self, oid: int, prefetch: bool = False,
                         rfo: bool = False) -> bool:
        """Disk -> memory. Returns True if this call performed the disk load
        (False: cached, or coalesced onto an in-flight load).  ``prefetch``
        marks the touch as prefetch-path for the eviction policy (a
        prefetch-aware policy must not count it as the application *using*
        the line).  ``rfo`` (prefetch only) dirty-allocates the line on
        landing — read-for-ownership for a statically-known update site, so
        the later write finds the line already owned.  Raises
        :class:`ServiceCrashed` on a dead service.

        Demand steal: if the oid is claimed by a batch lane that has not
        started loading it (``lane_pending`` on the in-flight event), a
        demand access takes the load over instead of waiting for the lane to
        reach it — the lane skips stolen oids when it finally gets a slot.
        The same event is reused, so coalesced waiters wake either way."""
        while True:
            flushes: list[tuple[DataService, int]] = []
            stole = False
            with self._cache_lock:
                if not self.alive:
                    raise ServiceCrashed(self.ds_id)
                if oid in self.cache:
                    flushes = self._touch(oid, prefetch=prefetch)
                    hit = True
                else:
                    hit = False
                    ev = self._inflight.get(oid)
                    if ev is None:
                        ev = threading.Event()
                        self._inflight[oid] = ev
                        owner = True
                    elif not prefetch and getattr(ev, "lane_pending", False):
                        ev.lane_pending = False
                        ev.stolen = True
                        self.demand_steals += 1
                        stole = True
                        owner = True
                    else:
                        owner = False
            if stole:
                tr = self._tracer
                if tr is not None:
                    tr.instant("demand-steal", service=self.ds_id, oid=oid)
            if hit:
                for vds, victim in flushes:
                    # flushing sleeps on a disk slot: never under the lock
                    vds._flush(victim)
                return False
            if owner:
                break
            ev.wait(timeout=5.0)
            # Re-check instead of assuming the load landed: the owner may
            # have timed out / been dropped (drop_cache wakes waiters with
            # nothing loaded).  A completed load still needs the LRU bump
            # this waiter's access deserves — both handled by looping back
            # to the cache check, which touches on hit and otherwise
            # performs (or re-coalesces onto) a fresh load.
            with self._cache_lock:
                if oid not in self.cache and self._inflight.get(oid) is ev and ev.is_set():
                    # the owner signalled but never landed the load: clear
                    # the stale entry so the next pass can take ownership
                    self._inflight.pop(oid, None)
        flushes = []
        try:
            if prefetch:
                # background load: let queued application loads go first
                self._yield_to_demand()
                slot = self._slots
            else:
                slot = self._demand_slot()
            with slot:
                if not self.alive:
                    raise ServiceCrashed(self.ds_id)
                self.latency.sleep(self.latency.disk_load_for(self.ds_id))
            with self._cache_lock:
                if not self.alive:
                    raise ServiceCrashed(self.ds_id)
                flushes = self._touch(oid, prefetch=prefetch)
                if rfo and prefetch:
                    self.dirty.add(oid)
                    self.rfo_prefetches += 1
        finally:
            with self._cache_lock:
                self._inflight.pop(oid, None)
            ev.set()
        for vds, victim in flushes:
            vds._flush(victim)
        self._beat()
        return True

    def _beat(self) -> None:
        """Heartbeat + per-load service-time sample for the fault detector
        (if the owning store has one attached): each landed load proves the
        service alive and feeds the straggler detector's timing baseline."""
        owner = self._owner
        if owner is not None and owner.fault is not None:
            owner.fault.beat(self.ds_id, self.latency.disk_load_for(self.ds_id))

    def crash(self) -> None:
        """Fail-stop this service: the memory cache and every in-flight
        load are lost (waiters wake and re-check — on a dead service the
        re-check raises, and the store's demand path fails over).  Disk
        contents are left in place: replicas on other services still share
        the same :class:`PersistentObject` records."""
        with self._cache_lock:
            self.alive = False
            for oid in self.cache:
                if self.budget is not None:
                    self.budget.note_remove(oid, self)
                else:
                    self.policy.note_remove(oid)
            self.cache.clear()
            for ev in self._inflight.values():
                ev.set()
            self._inflight.clear()
            self.dirty.clear()
            self._demand_waiting = 0
            self._demand_clear.set()

    def revive(self) -> None:
        """Bring a crashed service back to life with a COLD cache (crash
        already cleared it): loads and claims succeed again.  Routing
        readmission and anti-entropy resync are the owning store's job
        (``ObjectStore.revive_service``)."""
        with self._cache_lock:
            self.alive = True

    # -- batched prefetch dispatch ------------------------------------------

    def claim_prefetch_batch(self, oids: Iterable[int]) -> list[int]:
        """Dedupe a prefetch batch against cache and in-flight loads under
        ONE cache-lock acquisition (the per-oid path paid a lock round trip
        per object just to discover most of them were already resident).
        Already-cached oids get their policy bump (a prefetch touch, like
        the per-oid path's hit) and are suppressed; in-flight oids are
        suppressed outright (their load is coming).  Returns the oids still
        worth submitting, in request (= predicted-need) order.  Counters
        (``prefetch_requests`` / ``dedup_suppressed`` / ``batch_dispatches``)
        are charged here, under the same lock hold."""
        todo: list[int] = []
        claimed: set[int] = set()
        with self._cache_lock:
            if not self.alive:
                raise ServiceCrashed(self.ds_id)
            for oid in oids:
                self.prefetch_requests += 1
                if oid in claimed:
                    self.dedup_suppressed += 1  # duplicate within the batch
                elif oid in self.cache:
                    # resident: bump only (cannot overflow — no insert)
                    self.policy.note_access(oid, prefetch=True)
                    self.dedup_suppressed += 1
                elif oid in self._inflight:
                    self.dedup_suppressed += 1
                else:
                    claimed.add(oid)
                    todo.append(oid)
            if todo:
                self.batch_dispatches += 1
        return todo

    def load_batch(self, oids: Iterable[int], prefetch: bool = True,
                   pool=None, rfo: frozenset = frozenset()) -> None:
        """Load a batch of objects disk -> memory in request order,
        pipelining through this service's ``parallel_per_ds`` slots: with a
        pool, the batch splits into one lane per slot (strided, so the
        earliest-needed oids start first on every lane); without one, the
        calling worker drains the batch alone.  Unlike the per-oid path
        there is no per-object task submission and no store-wide
        metrics-lock traffic — landing a load costs one cache-lock
        acquisition (policy touch + in-flight clear together).  Oids in
        ``rfo`` dirty-allocate on landing (read-for-ownership)."""
        oids = list(oids)
        lanes = max(1, min(self.latency.parallel_per_ds, len(oids)))
        if pool is not None and lanes > 1:
            for i in range(1, lanes):
                pool.submit(self._load_lane, oids[i::lanes], prefetch, i, rfo)
            self._load_lane(oids[0::lanes], prefetch, 0, rfo)
        else:
            self._load_lane(oids, prefetch, rfo=rfo)

    #: loads claimed/slept/landed per lane iteration: one slot hold, one
    #: claim lock, one land lock per chunk (instead of per oid); bounds how
    #: long a demand access coalescing onto a claimed oid can wait
    _LANE_CHUNK = 4

    def _load_lane(self, oids: list[int], prefetch: bool, lane: int = 0,
                   rfo: frozenset = frozenset()) -> None:
        """One pipeline lane of a batched load: claim a chunk under one
        lock, occupy a disk arm for the chunk's sequential loads, land the
        chunk under one lock.  Oids that became resident (or in flight
        elsewhere) since the batch was deduped are skipped at claim time.
        With a tracer attached, each chunk records its slot wait vs disk
        service split (chunk-granular: the chunk shares one slot hold).

        Claimed-but-unstarted oids are *stealable*: a demand access for one
        of them flips the event's ``stolen`` flag and performs the load
        itself; this lane drops those from the chunk once it holds a slot
        (the event now belongs to the stealer).  If the service crashes
        mid-lane, every unlanded oid is handed back to the owning store for
        re-dispatch on a surviving replica."""
        tr = self._tracer
        pending = list(oids)
        while pending:
            if not self.alive:
                self._abort_lane(pending, rfo)
                return
            # the lane re-acquires the slot back-to-back; without this
            # yield a waiting demand load would lose every race for it
            self._yield_to_demand()
            chunk: list[tuple[int, threading.Event]] = []
            with self._cache_lock:
                while pending and len(chunk) < self._LANE_CHUNK:
                    oid = pending.pop(0)
                    if oid in self.cache:
                        # landed since the dispatch snapshot: bump, move on
                        self.policy.note_access(oid, prefetch=prefetch)
                    elif oid not in self._inflight:  # else: another loader owns it
                        ev = threading.Event()
                        ev.lane_pending = True  # steal window open
                        self._inflight[oid] = ev
                        chunk.append((oid, ev))
            if not chunk:
                continue
            t_q = time.perf_counter() if tr is not None else 0.0
            flushes: list[tuple[DataService, int]] = []
            try:
                with self._slots:
                    with self._cache_lock:
                        # steal handshake: demand took these over while the
                        # chunk queued for a slot — their events are now the
                        # stealers' to complete; load only the survivors
                        chunk = [(oid, ev) for oid, ev in chunk
                                 if not getattr(ev, "stolen", False)]
                        for _oid, ev in chunk:
                            ev.lane_pending = False
                        crashed = not self.alive
                    if crashed:
                        raise ServiceCrashed(self.ds_id)
                    if not chunk:
                        continue
                    t_s = time.perf_counter() if tr is not None else 0.0
                    # k sequential loads pipelined on one disk arm
                    self.latency.sleep(
                        self.latency.disk_load_for(self.ds_id) * len(chunk))
                    t_d = time.perf_counter() if tr is not None else 0.0
                with self._cache_lock:
                    if not self.alive:
                        raise ServiceCrashed(self.ds_id)
                    for oid, _ev in chunk:
                        flushes.extend(self._touch(oid, prefetch=prefetch))
                        self._inflight.pop(oid, None)
                        self.prefetch_loads += 1
                        if oid in rfo:
                            self.dirty.add(oid)
                            self.rfo_prefetches += 1
            except ServiceCrashed:
                with self._cache_lock:
                    for oid, _ev in chunk:
                        self._inflight.pop(oid, None)
                for _oid, ev in chunk:
                    ev.set()
                self._abort_lane([oid for oid, _ev in chunk] + pending, rfo)
                return
            except BaseException:
                with self._cache_lock:
                    for oid, _ev in chunk:
                        self._inflight.pop(oid, None)
                if tr is not None:
                    tr.dropped([oid for oid, _ev in chunk], "load-error")
                raise
            finally:
                for _oid, ev in chunk:
                    ev.set()
            if tr is not None:
                tr.loaded([oid for oid, _ev in chunk], self.ds_id, lane,
                          t_q, t_s, t_d)
            for vds, victim in flushes:
                vds._flush(victim)
            self._beat()

    def _abort_lane(self, oids: list[int], rfo: frozenset = frozenset()) -> None:
        """This service died mid-batch: hand every claimed-but-unlanded and
        still-pending oid back to the store, which re-dispatches them to a
        surviving replica (a no-op for a standalone service or when no
        replica is left — the demand path then eats the miss).  RFO marks
        survive the re-dispatch."""
        if not oids or self._owner is None:
            return
        self._owner._note_service_down(self.ds_id)
        self._owner._failover_redispatch(self.ds_id, oids, rfo=rfo)

    def write(self, oid: int) -> bool:
        """Write-allocate + write-back: ensure the object is in memory (a
        write to an uncached object performs the disk load and counts as a
        miss) and mark it dirty.  The ``write_back`` latency is deferred to
        eviction / ``drop_cache``, when the dirty line is flushed.  Returns
        True if this write performed the allocating disk load."""
        did_load = self.load_into_memory(oid)
        with self._cache_lock:
            if oid in self.cache:  # unless concurrently evicted already
                self.dirty.add(oid)
        return did_load

    def drop_cache(self) -> None:
        with self._cache_lock:
            for oid in self.cache:
                if self.budget is not None:
                    self.budget.note_remove(oid, self)
                else:
                    self.policy.note_remove(oid)
            self.cache.clear()
            for ev in self._inflight.values():
                ev.set()
            self._inflight.clear()
            dirty, self.dirty = self.dirty, set()
        for oid in dirty:
            self._flush(oid)


def prefetch_accuracy(prefetched: set, accessed: set) -> dict:
    """Set-based precision/recall of a prefetcher — shared between the live
    store accounting and the offline trace-replay harness
    (``predict.evaluate``), so both report identical definitions.

    A predictor that emitted nothing has *no* precision, not a precision of
    0.0 — the two used to be indistinguishable and recorded phantom zeros in
    comparison tables.  Undefined ratios are now ``None`` (rendered NaN-safe
    by consumers) and ``evaluated`` says whether any prefetch happened at
    all."""
    tp = len(prefetched & accessed)
    fp = len(prefetched - accessed)
    fn = len(accessed - prefetched)
    return {
        "true_positives": tp,
        "false_positives": fp,
        "false_negatives": fn,
        "precision": tp / (tp + fp) if tp + fp else None,
        "recall": tp / (tp + fn) if tp + fn else None,
        "evaluated": bool(prefetched),
    }


#: the prefetch-path counters that live on each DataService (the prefetch
#: path no longer touches the store-wide metrics lock); aggregated across
#: services by ``ObjectStore.snapshot_metrics``
PREFETCH_COUNTERS = (
    "prefetch_requests",
    "prefetch_loads",
    "batch_dispatches",
    "dedup_suppressed",
    "demand_steals",
    "rfo_prefetches",
)


@dataclass
class StoreMetrics:
    """Application-path counters (guarded by the store's metrics lock).
    Prefetch-path counters are per-service (``PREFETCH_COUNTERS``) so the
    background prefetch threads never contend with the application thread
    on this lock — read them via ``ObjectStore.snapshot_metrics``."""

    app_loads: int = 0
    app_cache_hits: int = 0
    app_cache_misses: int = 0
    remote_hops: int = 0
    writes: int = 0
    write_hits: int = 0  # writes that found the object already in memory
    dirty_evictions: int = 0  # evictions that had to flush a dirty object
    flushed_writes: int = 0  # write-backs actually performed (evict + drop)
    failovers: int = 0  # demand retries / batch re-dispatches off a dead service
    services_crashed: int = 0  # crash_service invocations (fault injection)
    stragglers_flagged: int = 0  # services the straggler detector deprioritized
    lost_writes: int = 0  # dirty flushes dropped on a dead service, no replica
    failover_retries: int = 0  # demand failover attempts beyond the first
    partitions: int = 0  # partition() invocations (fault injection)
    readmissions: int = 0  # services readmitted to routing (heal / revive)
    resync_lines: int = 0  # dirty lines anti-entropy replayed at readmission
    hedged_reads: int = 0  # demand reads that issued a second-replica hedge
    hedge_wins: int = 0  # hedged reads where the second replica answered first
    quorum_writes: int = 0  # replicated writes that reached their W-of-R quorum
    quorum_acks: int = 0  # synchronous replica acks charged (W-1 per write)
    quorum_retries: int = 0  # quorum attempts that backed off and retried
    quorum_failures: int = 0  # writes whose quorum stayed unreachable (sloppy)

    def snapshot(self) -> dict[str, int]:
        return dict(self.__dict__)


class ExecutionContext:
    """Tracks where the current application thread is executing (which Data
    Service) so navigation costs can charge execution redirection.

    Multi-tenant attribution rides here too: ``session_label`` stamps the
    demand spans this thread opens (per-call, never via shared tracer
    state), and ``stall_hist`` — a pre-resolved per-tenant histogram — gets
    every demand stall in addition to the per-service one."""

    def __init__(self, store: "ObjectStore", session_label: str = "",
                 stall_hist=None):
        self.store = store
        self.current_ds: Optional[int] = None
        self.session_label = session_label
        self.stall_hist = stall_hist


class ObjectStore:
    """The POS: N Data Services + placement + cost accounting."""

    def __init__(self, n_services: int = 4, latency: LatencyModel = ZERO,
                 cache_capacity: int = 0, cache_policy: str = DEFAULT_POLICY,
                 shared_budget: bool = False,
                 placement: str = DEFAULT_PLACEMENT, replication: int = 1,
                 write_quorum: int = 1, hedge: bool = False,
                 hedge_delay: Optional[float] = None):
        self.latency = latency
        self.cache_policy = cache_policy
        # shared-memory-budget mode: ``cache_capacity`` is one global line
        # budget all services draw from (policy-mediated stealing), instead
        # of a fixed per-service capacity
        self.budget = (
            SharedBudget(cache_capacity, policy=cache_policy)
            if shared_budget and cache_capacity
            else None
        )
        self.services = [
            DataService(i, latency,
                        0 if self.budget is not None else cache_capacity,
                        policy=cache_policy, budget=self.budget)
            for i in range(n_services)
        ]
        for ds in self.services:
            ds._owner = self
        # placement is a policy (pos.placement), the way eviction is: the
        # policy returns each new object's replica set (primary first)
        self.placement_name = placement
        self.replication = max(1, min(replication, n_services))
        self._placer = make_placement(placement, n_services, self.replication)
        self._placement: dict[int, tuple[int, ...]] = {}  # oid -> replica set
        # creation log (oid, cls, group, pinned_ds) — enough to re-place the
        # whole store under a different policy (rebuild_placement) and to
        # snapshot/restore placement inputs for the trace cache
        self._put_log: list[tuple[int, str, Optional[str], Optional[int]]] = []
        # failure bookkeeping: services routing must avoid (detected dead)
        # and deprioritize (detector-flagged stragglers).  A crashed-but-
        # unannounced service stays routable until the error path or the
        # heartbeat monitor catches it — that window IS the failure model.
        self._down: set[int] = set()
        self._slow: set[int] = set()
        # network partition ground truth: services currently unreachable
        # from the client-side group (partition()/heal_partition()) —
        # distinct from _down, which is *detected* state.  Traffic routed
        # to a cut service fails exactly like a crash.
        self._net_cut: set[int] = set()
        # anti-entropy write log: replica -> oids whose writes it missed
        # while dead/partitioned; resynced (flushed on the replica) at
        # readmission.  Guarded by the metrics lock (writes hold it anyway).
        self._missed_writes: dict[int, set[int]] = {}
        # per-tenant failover attribution (session label -> count), for the
        # multi-tenant harness; guarded by the metrics lock
        self.failovers_by_session: dict[str, int] = {}
        # write quorum: replicated writes wait for W-of-R synchronous
        # replica acks (1 = async/sloppy, the legacy behavior)
        self.write_quorum = max(1, min(write_quorum, self.replication))
        # hedged reads: demand misses issue to a second replica after
        # hedge_delay and take the first response (None = derive the delay
        # from observed p99 stall, fallback 3x disk_load)
        self.hedge = hedge
        self.hedge_delay = hedge_delay
        self.fault = None  # optional runtime.fault.StoreFaultDetector
        self._oid_counter = itertools.count(1)
        self._metrics_lock = threading.Lock()
        self.metrics = StoreMetrics()
        # accuracy accounting (true/false positives of prefetching) — the
        # prefetched set has its own lock so prefetch threads recording
        # their work never block the application thread's metrics updates
        self._prefetch_lock = threading.Lock()
        self.accessed_oids: set[int] = set()
        self.prefetched_oids: set[int] = set()
        # live PrefetchRuntimes attached by Sessions: reset_runtime_state
        # drains them so straggler prefetch tasks from one benchmark
        # repetition cannot leak work into the next
        self._runtimes: set = set()
        # set to [] to record the application's event stream as schema-v2
        # TraceEvent records (access / write / method_entry — pos.trace)
        self.trace: Optional[list[TraceEvent]] = None
        # optional callback fired on every application-path cache miss —
        # how the ROP baseline hooks its eager referenced-object fetch
        self.miss_listener = None
        # optional callback fired on EVERY application-path access (hit or
        # miss) — the monitoring hook the trace-mined predictors pay for
        self.access_listener = None
        # observability context (repro.obs.Observability): attach_obs wires
        # the metrics registry + optional span tracer; None = uninstrumented
        # (the hot paths then skip every obs branch)
        self.obs = None
        self._stall_hists: Optional[dict[int, Any]] = None

    def attach_obs(self, obs) -> None:
        """Attach an ``Observability`` context: registers this store's
        metrics as a registry source and pre-resolves the per-service demand
        stall histograms so the application path never hits the registry's
        lookup lock.  Span tracing activates iff ``obs.tracer`` is set."""
        self.obs = obs
        obs.registry.register_source("store", self.snapshot_metrics)
        self._stall_hists = {
            ds.ds_id: obs.registry.histogram("demand_stall_s", service=ds.ds_id)
            for ds in self.services
        }

    # -- placement ---------------------------------------------------------

    def new_oid(self) -> int:
        return next(self._oid_counter)

    def put(self, cls: str, fields: Optional[dict[str, Any]] = None,
            ds: Optional[int] = None, group: Optional[str] = None) -> int:
        """Store a new object; the placement policy picks its replica set
        (primary first) unless pinned to ``ds``.  ``group`` is the locality
        hint — apps tag a collection element's whole subtree with one key so
        the locality policy co-locates it (other policies ignore it).  With
        ``replication > 1`` the same record lands on R services: one
        :class:`PersistentObject` instance shared by all replica disks, so
        field state is trivially consistent (this is a latency/availability
        model, not a durability protocol).  Pinned puts do not advance the
        policy — the legacy contract that keeps pinning side-effect-free."""
        oid = self.new_oid()
        if ds is None:
            reps = self._placer.place(oid, cls, group=group)
        else:
            reps = placement_spread(ds, len(self.services), self.replication)
        obj = PersistentObject(oid=oid, cls=cls, fields=fields or {})
        for r in reps:
            self.services[r].disk[oid] = obj
        self._placement[oid] = reps
        self._put_log.append((oid, cls, group, ds))
        return oid

    def service_of(self, oid: int) -> DataService:
        """The object's *primary* Data Service (replica set's first entry —
        what the virtual-clock replay and placement-agnostic callers use)."""
        return self.services[self._placement[oid][0]]

    def replicas_of(self, oid: int) -> tuple[int, ...]:
        return self._placement[oid]

    def record(self, oid: int) -> PersistentObject:
        # any replica works (shared instance); read the primary's disk
        return self.service_of(oid).disk[oid]

    def cls_of(self, oid: int) -> str:
        return self.record(oid).cls

    def rebuild_placement(self, placement: str, replication: int = 1) -> None:
        """Re-place every stored object under a different policy and/or
        replication factor without re-recording anything: replay the
        creation log (same order, same group hints, pins respected) through
        a fresh policy instance.  Determinism of the policies guarantees the
        result is identical to having created the store this way.  Caches
        must be cold (use between replays, not mid-run)."""
        n = len(self.services)
        records = {oid: self.record(oid) for oid, _cls, _grp, _pin in self._put_log}
        self.placement_name = placement
        self.replication = max(1, min(replication, n))
        self._placer = make_placement(placement, n, self.replication)
        self._placement.clear()
        for svc in self.services:
            svc.disk.clear()
        for oid, cls, group, pin in self._put_log:
            if pin is None:
                reps = self._placer.place(oid, cls, group=group)
            else:
                reps = placement_spread(pin, n, self.replication)
            self._placement[oid] = reps
            for r in reps:
                self.services[r].disk[oid] = records[oid]

    # -- replica routing -----------------------------------------------------

    def _pick_replica(self, oid: int, alive: list[int],
                      reps: tuple[int, ...]) -> DataService:
        """Load-aware replica choice: prefer a replica that already holds
        (or is loading) the line — prefetch landed it there — else the
        least-queued non-straggler; ties break in replica order, primary
        first.  The racy cache/inflight peeks are deliberate (routing is a
        hint; correctness lives in load_into_memory)."""
        for i in alive:
            ds = self.services[i]
            if oid in ds.cache or oid in ds._inflight:
                return ds
        return self.services[min(
            alive,
            key=lambda i: (i in self._slow,
                           self.services[i]._demand_waiting
                           + len(self.services[i]._inflight),
                           reps.index(i)),
        )]

    def _route_demand(self, oid: int) -> DataService:
        """Pick the replica a demand access should execute on.  Routing
        consults *detected* state only (``_down``): a crashed service that
        nobody has noticed yet still gets traffic — the resulting
        :class:`ServiceCrashed` is how the error path detects it."""
        reps = self._placement[oid]
        if len(reps) == 1:  # replication 1: byte-identical legacy routing
            if reps[0] in self._down:
                raise NoReplicaAvailable(oid, reps)
            return self.services[reps[0]]
        alive = [i for i in reps if i not in self._down]
        if not alive:
            raise NoReplicaAvailable(oid, reps)
        if len(alive) == 1:
            return self.services[alive[0]]
        return self._pick_replica(oid, alive, reps)

    def _route_prefetch(self, oid: int) -> Optional[DataService]:
        """Like ``_route_demand`` but a prefetch with no reachable replica
        is silently skipped (None) — the demand path will surface the
        failure if the object is ever actually needed."""
        reps = self._placement[oid]
        if len(reps) == 1:
            return None if reps[0] in self._down else self.services[reps[0]]
        alive = [i for i in reps if i not in self._down]
        if not alive:
            return None
        if len(alive) == 1:
            return self.services[alive[0]]
        return self._pick_replica(oid, alive, reps)

    # -- application-path access -------------------------------------------

    def _redirect(self, ctx: Optional[ExecutionContext], ds: DataService) -> None:
        """Charge execution redirection if the application thread is not
        already on the owning Data Service."""
        if ctx is not None and ctx.current_ds != ds.ds_id:
            self.latency.sleep(self.latency.remote_hop)
            ctx.current_ds = ds.ds_id
            with self._metrics_lock:
                self.metrics.remote_hops += 1

    def _notify(self, oid: int, did_load: bool) -> None:
        """Fire the demand-path listeners (shared by reads and writes, so
        the monitoring family observes the full get/put stream)."""
        if did_load and self.miss_listener is not None:
            self.miss_listener(oid)
        if self.access_listener is not None:
            self.access_listener(oid)

    #: bounded demand-path failover budget: a full-outage oid fails fast
    #: (RetryExhausted) instead of spinning on routing that keeps landing
    #: on corpses; each retry backs off exponentially on failover_detect
    MAX_FAILOVER_RETRIES = 4

    def _demand_load(self, ctx: Optional[ExecutionContext], oid: int,
                     write: bool = False) -> tuple[DataService, bool]:
        """Demand access with failover: route to a replica, redirect
        execution, load (or write-allocate).  A :class:`ServiceCrashed`
        marks the service down, charges ``failover_detect`` (exponentially
        backed off per retry), and retries on a surviving replica —
        :class:`NoReplicaAvailable` escapes when none is left, and
        :class:`RetryExhausted` after ``MAX_FAILOVER_RETRIES`` failed
        attempts.  A service across a network partition fails exactly like
        a crashed one.  With hedging armed, a read that outlives the hedge
        delay issues to a second replica and takes the first response.  The
        stall histogram/span covers the WHOLE wait including failed
        attempts (that is what the application thread experienced)."""
        obs = self.obs
        t0 = time.perf_counter() if obs is not None else 0.0
        attempts = 0
        while True:
            ds = self._route_demand(oid)
            self._redirect(ctx, ds)
            try:
                if ds.ds_id in self._net_cut:
                    raise ServiceCrashed(ds.ds_id)
                if self.hedge and not write:
                    did_load, ds = self._hedged_load(oid, ds)
                else:
                    did_load = ds.write(oid) if write else ds.load_into_memory(oid)
                break
            except ServiceCrashed as exc:
                attempts += 1
                self._note_service_down(exc.ds_id)
                label = ctx.session_label if ctx is not None else ""
                with self._metrics_lock:
                    self.metrics.failovers += 1
                    if attempts > 1:
                        self.metrics.failover_retries += 1
                    self.failovers_by_session[label] = (
                        self.failovers_by_session.get(label, 0) + 1)
                tr = obs.tracer if obs is not None else None
                if tr is not None:
                    tr.instant("demand-failover", service=exc.ds_id, oid=oid)
                if attempts > self.MAX_FAILOVER_RETRIES:
                    raise RetryExhausted(oid, attempts) from exc
                self.latency.sleep(
                    self.latency.failover_detect * (2 ** (attempts - 1)))
        if obs is not None:
            stall = time.perf_counter() - t0
            self._stall_hists[ds.ds_id].record(stall)
            if ctx is not None and ctx.stall_hist is not None:
                ctx.stall_hist.record(stall)
            if obs.tracer is not None:
                obs.tracer.demand(oid, ds.ds_id, t0, stall, did_load,
                                  self.latency.disk_load_for(ds.ds_id),
                                  session=ctx.session_label if ctx is not None
                                  else "")
        if self.fault is not None:
            self.fault.tick()
        return ds, did_load

    # -- hedged reads --------------------------------------------------------

    def _hedge_delay_for(self, ds_id: int) -> float:
        """The wait before a hedge fires: an explicit ``hedge_delay`` wins;
        else the observed p99 demand stall on the primary service (needs
        >= 32 samples so early noise cannot arm hair-trigger hedges); else
        3x the nominal disk load — roughly where a queued-or-degraded load
        separates from a healthy one."""
        if self.hedge_delay is not None:
            return self.hedge_delay
        if self._stall_hists is not None:
            hist = self._stall_hists.get(ds_id)
            if hist is not None and hist.count >= 32:
                (p99,) = hist.percentiles((0.99,))
                if p99:
                    return p99
        return 3 * self.latency.disk_load

    def _hedge_alt(self, oid: int, primary: DataService) -> Optional[DataService]:
        """The second replica a hedged read would issue to: any reachable
        replica other than the primary, least-queued first."""
        reps = self._placement[oid]
        alts = [i for i in reps
                if i != primary.ds_id and i not in self._down
                and i not in self._net_cut and self.services[i].alive]
        if not alts:
            return None
        return self.services[min(
            alts,
            key=lambda i: (self.services[i]._demand_waiting
                           + len(self.services[i]._inflight),
                           reps.index(i)),
        )]

    def _hedged_load(self, oid: int,
                     primary: DataService) -> tuple[bool, DataService]:
        """Speculative-read demand load: issue to ``primary``; if it has not
        answered within the hedge delay, issue the same load to a second
        replica and take whichever answers first (both loads run to
        completion — the loser's disk time is the price of the tail cut).
        Returns ``(did_load, winning_service)``.  With no second replica
        available this degrades to a plain load."""
        alt = self._hedge_alt(oid, primary)
        if alt is None:
            return primary.load_into_memory(oid), primary
        outcome: dict[str, Any] = {}
        done = threading.Event()
        lock = threading.Lock()

        def _primary() -> None:
            try:
                dl = primary.load_into_memory(oid)
                with lock:
                    outcome.setdefault("win", (primary, dl))
            except BaseException as exc:  # surfaced if the hedge loses too
                with lock:
                    outcome.setdefault("primary_error", exc)
            done.set()

        th = threading.Thread(target=_primary, daemon=True,
                              name=f"hedge-primary-{primary.ds_id}")
        th.start()
        if done.wait(self._hedge_delay_for(primary.ds_id)):
            with lock:
                if "win" in outcome:
                    ds, dl = outcome["win"]
                    return dl, ds
            # primary failed fast: the hedge below is also the failover
        with self._metrics_lock:
            self.metrics.hedged_reads += 1
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("hedged-read", service=alt.ds_id, oid=oid)
        try:
            dl = alt.load_into_memory(oid)
        except BaseException:
            done.wait(5.0)
            with lock:
                if "win" in outcome:
                    ds, dl = outcome["win"]
                    return dl, ds
            raise
        with lock:
            outcome.setdefault("win", (alt, dl))
            ds, dl = outcome["win"]
        if ds is alt:
            with self._metrics_lock:
                self.metrics.hedge_wins += 1
        return dl, ds

    # -- write quorum --------------------------------------------------------

    #: bounded quorum wait: attempts before a replicated write gives up on
    #: its W-of-R quorum and degrades to sloppy (QuorumUnreachable)
    MAX_QUORUM_RETRIES = 4

    def _await_write_quorum(self, oid: int, ds: DataService) -> None:
        """Synchronous W-of-R replication for a write: wait until at least
        ``write_quorum`` replicas are reachable (ground truth — acks need
        live services, not routing guesses), charge one ``remote_hop`` per
        extra ack, and propagate the dirty bit to the acking replicas'
        resident lines.  Unreachable quorums retry with exponential backoff
        (a healing partition can unblock a waiter), then surface as
        :class:`QuorumUnreachable` — the local write stands (sloppy), the
        caller learns consistency was not achieved."""
        reps = self._placement[oid]
        want = min(self.write_quorum, len(reps))
        if want <= 1:
            return
        backoff = max(self.latency.failover_detect, self.latency.disk_load)
        reachable: list[int] = []
        for attempt in range(self.MAX_QUORUM_RETRIES + 1):
            reachable = [r for r in reps
                         if self.services[r].alive and r not in self._net_cut]
            if len(reachable) >= want:
                acks = want - 1
                for _ in range(acks):
                    self.latency.sleep(self.latency.remote_hop)
                for r in reachable:
                    if r == ds.ds_id:
                        continue
                    svc = self.services[r]
                    with svc._cache_lock:
                        if oid in svc.cache:
                            svc.dirty.add(oid)
                with self._metrics_lock:
                    self.metrics.quorum_writes += 1
                    self.metrics.quorum_acks += acks
                return
            if attempt == self.MAX_QUORUM_RETRIES:
                break
            with self._metrics_lock:
                self.metrics.quorum_retries += 1
            self.latency.sleep(backoff * (2 ** attempt))
        with self._metrics_lock:
            self.metrics.quorum_failures += 1
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("quorum-unreachable", service=ds.ds_id, oid=oid,
                       wanted=want, got=len(reachable))
        raise QuorumUnreachable(oid, want, len(reachable))

    def app_access(self, ctx: ExecutionContext, oid: int) -> PersistentObject:
        """Navigate to ``oid`` on the application thread: redirect execution
        to a replica holding the object (load-aware choice under
        replication), then ensure the object is in that service's memory —
        failing over to another replica if the service turns out dead."""
        ds, did_load = self._demand_load(ctx, oid)
        with self._metrics_lock:
            self.metrics.app_loads += 1
            if did_load:
                self.metrics.app_cache_misses += 1
            else:
                self.metrics.app_cache_hits += 1
            self.accessed_oids.add(oid)
            if self.trace is not None:
                self.trace.append(access_event(oid))
        self._notify(oid, did_load)
        self.latency.sleep(self.latency.think)
        return ds.disk[oid]

    def app_write(self, oid: int, ctx: Optional[ExecutionContext] = None) -> None:
        """Update ``oid`` on the application thread.  Writes are demand
        accesses like any other: execution redirects to the owning Data
        Service, an uncached object is write-allocated (the disk load counts
        as a miss), the dirty bit defers ``write_back`` to eviction/flush,
        and the access is visible to tracing, ``accessed_oids`` and the
        listeners — previously all of this was bypassed and mutating
        workloads undercounted demand."""
        ds, did_load = self._demand_load(ctx, oid, write=True)
        if self.write_quorum > 1:
            self._await_write_quorum(oid, ds)
        reps = self._placement[oid]
        with self._metrics_lock:
            self.metrics.writes += 1
            if did_load:
                self.metrics.app_cache_misses += 1
            else:
                self.metrics.write_hits += 1
            self.accessed_oids.add(oid)
            if self.trace is not None:
                self.trace.append(write_event(oid))
            # anti-entropy log: replicas that cannot see this write (dead
            # or across the partition) resync the line at readmission
            if len(reps) > 1:
                for r in reps:
                    if r == ds.ds_id:
                        continue
                    if (r in self._net_cut or r in self._down
                            or not self.services[r].alive):
                        self._missed_writes.setdefault(r, set()).add(oid)
        self._notify(oid, did_load)
        # per-object application processing charges on writes exactly like
        # reads — the virtual-clock replay does the same, keeping the two
        # timelines comparable
        self.latency.sleep(self.latency.think)

    def trace_method_entry(self, method_key: str, oid: int) -> None:
        """Record entry into a registered method (the injected scheduling
        point) in the event trace — no cost, pure bookkeeping."""
        with self._metrics_lock:
            if self.trace is not None:
                self.trace.append(method_entry_event(method_key, oid))

    def _note_dirty_eviction(self) -> None:
        with self._metrics_lock:
            self.metrics.dirty_evictions += 1

    def _note_flush(self) -> None:
        with self._metrics_lock:
            self.metrics.flushed_writes += 1

    # -- prefetch-path access ----------------------------------------------

    def prefetch_access(self, oid: int, origin: str = "", rfo: bool = False,
                        session: str = "") -> PersistentObject:
        """Per-oid prefetch: load ``oid`` into its own Data Service's memory
        (no execution redirection: 'dataClay ... loads the object where it
        is stored').  This is the legacy one-task-per-oid dispatch target
        (``dispatch="per-oid"``); each call was one executor submission, so
        it also counts one ``batch_dispatches``.  ``rfo`` dirty-allocates
        the line (the static optimizer marked it a known update site)."""
        with self._prefetch_lock:
            self.prefetched_oids.add(oid)
        ds = self._route_prefetch(oid)
        if ds is None:
            return self.record(oid)  # no reachable replica: skip quietly
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.predicted([oid], origin, session=session)
            tr.dispatched([oid], ds.ds_id, tr.new_batch(), session=session)
            t_q = time.perf_counter()
            tr.claimed([oid], ds.ds_id, t=t_q)
        try:
            if ds.ds_id in self._net_cut:
                raise ServiceCrashed(ds.ds_id)
            did_load = ds.load_into_memory(oid, prefetch=True, rfo=rfo)
        except ServiceCrashed:
            self._note_service_down(ds.ds_id)
            self._failover_redispatch(
                ds.ds_id, [oid], rfo=frozenset([oid]) if rfo else frozenset(),
                session=session)
            return self.record(oid)
        if tr is not None:
            if did_load:
                # per-oid loads have no slot-wait visibility: the whole
                # load_into_memory window counts as queue+disk
                tr.loaded([oid], ds.ds_id, 0, t_q, t_q, time.perf_counter())
            else:
                tr.suppressed([oid], ds.ds_id)
        with ds._cache_lock:
            ds.prefetch_requests += 1
            ds.batch_dispatches += 1
            if did_load:
                ds.prefetch_loads += 1
        return ds.disk[oid]

    def prefetch_batch(self, oids: Iterable[int], runtime=None,
                       origin: str = "", rfo: Iterable[int] = (),
                       priorities: Optional[dict[int, float]] = None,
                       session: str = "") -> int:
        """Batched, placement-aware prefetch dispatch: group the predicted
        ``oids`` (already in predicted-need order) by owning Data Service,
        dedupe each group against that service's cache *and* in-flight loads
        under one snapshot read, and submit **one batch task per Data
        Service** whose worker pipelines the surviving loads through the
        service's ``parallel_per_ds`` slots.  All requested oids count as
        prefetched for accuracy (exactly what the per-oid path records);
        suppressed ones are tallied in the per-service ``dedup_suppressed``.
        Without a ``runtime`` the batches load on the calling thread.
        Returns the number of batch tasks submitted.

        Static-optimizer signals: oids in ``rfo`` dirty-allocate on landing
        (read-for-ownership); ``priorities`` (oid -> static dispatch
        priority) orders the per-service groups most-valuable-first and
        feeds the runtime's admission control — a saturated runtime sheds
        the cheap-to-skip expensive tail (``runtime.admit``) instead of
        queueing unboundedly.

        Under replication the grouping routes each oid to its best replica
        (cached/least-queued), and a batch that lands on a service that
        crashed between routing and claiming is re-dispatched to the
        survivors instead of being lost."""
        oids = list(oids)
        rfo = frozenset(rfo)
        groups: dict[int, list[int]] = {}
        skipped = 0
        for oid in oids:
            ds = self._route_prefetch(oid)
            if ds is None:
                skipped += 1  # unreachable: demand will surface it if needed
                continue
            groups.setdefault(ds.ds_id, []).append(oid)
        with self._prefetch_lock:
            self.prefetched_oids.update(oids)
        if not groups:
            return 0
        ordered = list(groups.items())
        if priorities:
            # highest-priority group first (stable on the original
            # predicted-need grouping order for ties)
            ordered.sort(key=lambda kv: -max(
                (priorities.get(o, 0.0) for o in kv[1]), default=0.0))
        tr = self.obs.tracer if self.obs is not None else None
        submitted = 0
        for ds_id, batch in ordered:
            ds = self.services[ds_id]
            if tr is not None:
                tr.predicted(batch, origin, session=session)
            if runtime is not None and priorities is not None:
                prio = max((priorities.get(o, 0.0) for o in batch),
                           default=0.0)
                if not runtime.admit(prio):
                    if tr is not None:
                        tr.dropped(batch, "admission")
                    continue
            if tr is not None:
                tr.dispatched(batch, ds_id, tr.new_batch(), session=session)
            try:
                if ds_id in self._net_cut:
                    raise ServiceCrashed(ds_id)
                todo = ds.claim_prefetch_batch(batch)
            except ServiceCrashed:
                self._note_service_down(ds_id)
                self._failover_redispatch(ds_id, batch, runtime=runtime,
                                          origin=origin, rfo=rfo,
                                          session=session)
                continue
            if tr is not None:
                if todo:
                    tr.claimed(todo, ds_id)
                won = set(todo)
                lost = [o for o in batch if o not in won]
                if lost:
                    tr.suppressed(lost, ds_id)
            if not todo:
                continue
            submitted += 1
            todo_rfo = rfo.intersection(todo)
            if runtime is not None:
                runtime.submit(ds.load_batch, todo, True, runtime, todo_rfo)
            else:
                ds.load_batch(todo, rfo=todo_rfo)
        return submitted

    def peek(self, oid: int) -> PersistentObject:
        """Read a record without cost accounting (builders / assertions)."""
        return self.record(oid)

    # -- failure injection & detection ---------------------------------------

    def crash_service(self, ds_id: int, announce: bool = True) -> None:
        """Fail-stop one Data Service: its memory cache and in-flight loads
        are gone (disk records survive on the replicas).  ``announce=False``
        models a *silent* failure — routing keeps sending traffic there
        until the error path or the heartbeat monitor notices."""
        self.services[ds_id].crash()
        with self._metrics_lock:
            self.metrics.services_crashed += 1
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("service-crash", service=ds_id)
        if announce:
            self._note_service_down(ds_id)

    def partition(self, groups: Iterable[Iterable[int]],
                  announce: bool = True) -> None:
        """Cut the network into ``groups`` of service ids: group 0 is the
        client-side majority (services listed in no group implicitly belong
        to it); every service outside group 0 becomes unreachable — demand
        and prefetch traffic to it fails like :class:`ServiceCrashed` and
        routing degrades to the reachable replicas.  Unlike a crash the cut
        services keep their memory state: at ``heal_partition`` they rejoin
        warm and resync only the writes they missed.  ``announce=False``
        models an undetected cut (traffic keeps flowing until the error
        path notices)."""
        groups = [tuple(g) for g in groups]
        cut = {ds_id for grp in groups[1:] for ds_id in grp}
        self._net_cut = cut
        with self._metrics_lock:
            self.metrics.partitions += 1
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("partition", cut=sorted(cut))
        if announce:
            for ds_id in cut:
                self._note_service_down(ds_id)

    def heal_partition(self) -> None:
        """Heal the network cut: every cut service readmits into routing
        (warm cache — nothing was lost, only unreachable) and anti-entropy
        resyncs the dirty lines whose writes it missed."""
        cut, self._net_cut = self._net_cut, set()
        for ds_id in sorted(cut):
            self._readmit(ds_id)
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("partition-heal", healed=sorted(cut))

    def revive_service(self, ds_id: int) -> None:
        """Bring a crashed service back: cold cache, healthy routing state,
        heartbeat/straggler detector readmission, and anti-entropy resync
        of the writes it missed while dead."""
        self.services[ds_id].revive()
        self._readmit(ds_id)
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("service-readmit", service=ds_id)

    # back-compat alias (pre-recovery API)
    def restore_service(self, ds_id: int) -> None:
        self.revive_service(ds_id)

    def _readmit(self, ds_id: int) -> None:
        """Shared readmission path (heal + revive): routing forgets the
        down/straggler flags, the fault detector resets the service's
        baseline, missed writes resync, and the readmission is counted."""
        self._down.discard(ds_id)
        self._slow.discard(ds_id)
        if self.fault is not None:
            self.fault.readmit(ds_id)
        resynced = self._resync_missed(ds_id)
        with self._metrics_lock:
            self.metrics.readmissions += 1
            self.metrics.resync_lines += resynced

    def _resync_missed(self, ds_id: int) -> int:
        """Anti-entropy replay of the write log a returning replica missed:
        each missed oid costs the replica one write-back (off the
        application's critical path — charged on the replica's own disk
        slots).  Returns the number of lines resynced."""
        with self._metrics_lock:
            missed = self._missed_writes.pop(ds_id, set())
        ds = self.services[ds_id]
        count = 0
        for oid in sorted(missed):
            if oid in ds.disk:
                ds._flush(oid)
                count += 1
        return count

    def _flush_failover(self, from_ds: int, oid: int) -> bool:
        """A dirty flush landed on a dead service: perform the write-back
        on a live reachable replica instead of dropping the update.  False
        when no replica can take it (the caller counts a lost write)."""
        reps = self._placement.get(oid, ())
        for r in reps:
            if r == from_ds:
                continue
            svc = self.services[r]
            if svc.alive and r not in self._net_cut and oid in svc.disk:
                svc._flush(oid)
                return True
        return False

    def _note_lost_write(self, ds_id: int, oid: int) -> None:
        with self._metrics_lock:
            self.metrics.lost_writes += 1
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("lost-write", service=ds_id, oid=oid)

    def attach_fault_detection(self, **kwargs) -> "Any":
        """Wire the ``runtime.fault`` machinery (HeartbeatMonitor +
        StragglerDetector) into this store: landed loads beat, the demand
        path ticks, missed beats mark services down and persistent disk-time
        outliers get deprioritized by routing."""
        from ..runtime.fault import StoreFaultDetector

        self.fault = StoreFaultDetector(self, **kwargs)
        return self.fault

    def _note_service_down(self, ds_id: int) -> None:
        """Record a detected-dead service (error path, heartbeat timeout or
        explicit announce); idempotent, routing avoids it from now on."""
        if ds_id in self._down:
            return
        self._down.add(ds_id)
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("service-down", service=ds_id)

    def _note_straggler(self, ds_id: int) -> None:
        """Record a detector-flagged straggler: routing deprioritizes it
        when a healthier replica exists (it stays available — slow, not
        dead)."""
        if ds_id in self._slow:
            return
        self._slow.add(ds_id)
        with self._metrics_lock:
            self.metrics.stragglers_flagged += 1
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.instant("straggler-flagged", service=ds_id)

    def _failover_redispatch(self, from_ds: int, oids: list[int],
                             runtime=None, origin: str = "failover",
                             rfo: frozenset = frozenset(),
                             session: str = "") -> int:
        """Re-dispatch prefetch oids that were claimed by (or headed for) a
        service that died before landing them.  Routing now avoids the dead
        service, so the batch re-groups onto surviving replicas; with
        replication 1 there is nowhere to go and the oids fall back to
        demand misses.  RFO marks survive the re-dispatch."""
        if not oids:
            return 0
        with self._metrics_lock:
            self.metrics.failovers += 1
        tr = self.obs.tracer if self.obs is not None else None
        if tr is not None:
            tr.dropped(oids, "service-crash")
            tr.instant("prefetch-failover", service=from_ds, oids=len(oids))
        return self.prefetch_batch(oids, runtime=runtime,
                                   origin=origin or "failover",
                                   rfo=rfo.intersection(oids),
                                   session=session)

    # -- bookkeeping ---------------------------------------------------------

    def snapshot_metrics(self) -> dict[str, int]:
        """One coherent metrics read: the application-path ``StoreMetrics``
        plus the per-service prefetch counters summed across Data Services
        (the per-oid prefetch path used to update the store-wide metrics
        under the same lock the application thread takes on every access —
        aggregation now happens here, on read, instead)."""
        with self._metrics_lock:
            out = self.metrics.snapshot()
        for key in PREFETCH_COUNTERS:
            out[key] = 0
        for ds in self.services:
            with ds._cache_lock:
                for key in PREFETCH_COUNTERS:
                    out[key] += getattr(ds, key)
        return out

    def register_runtime(self, runtime) -> None:
        """Attach a live PrefetchRuntime (Session does this) so
        ``reset_runtime_state`` can drain outstanding prefetch work."""
        self._runtimes.add(runtime)

    def unregister_runtime(self, runtime) -> None:
        self._runtimes.discard(runtime)

    def protected_evictions(self) -> int:
        """Evictions where the policy passed over protected prefetched
        lines (store-wide; the shared budget's policy already spans all
        services, so count each policy instance once)."""
        policies = {id(ds.policy): ds.policy for ds in self.services}
        return sum(p.protected_evictions for p in policies.values())

    def reset_runtime_state(self, drain_timeout: float = 5.0) -> None:
        """Drop all caches and counters (between benchmark repetitions).
        Any Session-attached PrefetchRuntime is drained first — straggler
        prefetch tasks from repetition *k* used to keep loading into the
        freshly reset caches and pollute repetition *k+1*'s metrics; a
        drain timeout is now surfaced as a warning and the runtime is
        hard-drained (queued work cancelled) rather than ignored.
        ``drop_cache`` then flushes dirty write-back state; the per-service
        counters (``evictions`` et al.) are zeroed too — they used to
        survive resets and accumulate across repetitions."""
        for runtime in list(self._runtimes):
            if not runtime.drain(drain_timeout):
                import warnings

                warnings.warn(
                    "prefetch work still outstanding at reset_runtime_state "
                    f"after {drain_timeout}s; hard-draining so stragglers "
                    "cannot pollute the next repetition",
                    RuntimeWarning,
                    stacklevel=2,
                )
                runtime.hard_drain(drain_timeout)
        if self.obs is not None and self.obs.tracer is not None:
            # lifecycle invariant through resets: whatever is still live
            # (cancelled work, never-demanded residents) terminates now
            self.obs.tracer.drop_active("drained")
        for ds in self.services:
            ds.drop_cache()
            ds.reset_counters()
        if self.budget is not None:
            self.budget.reset()
        with self._metrics_lock:
            self.metrics = StoreMetrics()
            self.accessed_oids = set()
            self.prefetched_oids = set()
            self.failovers_by_session = {}
            self._missed_writes = {}
            if self.trace is not None:
                self.trace = []

    # -- accuracy ------------------------------------------------------------

    def prefetch_accuracy(self) -> dict[str, float]:
        """True positives: prefetched & accessed. False positives: prefetched
        but never accessed. False negatives: accessed but never prefetched."""
        return prefetch_accuracy(self.prefetched_oids, self.accessed_oids)

    def populate_collection(self, cls: str, payloads: Iterable[dict[str, Any]],
                            groups: Optional[Iterable[Optional[str]]] = None) -> list[int]:
        """Store many objects of one class distributed across Data Services
        by the placement policy (how dataClay distributes a stored
        collection).  ``groups`` optionally supplies one locality hint per
        payload (element subtree keys for the locality policy)."""
        if groups is None:
            return [self.put(cls, p) for p in payloads]
        return [self.put(cls, p, group=g) for p, g in zip(payloads, groups)]
