"""A dataClay-like distributed Persistent Object Store (paper section 6).

Objects are distributed across Data Services; method execution follows the
objects (execution requests are redirected to the Data Service storing the
receiver); prefetching warms each Data Service's local memory from its own
disk, in parallel across services.
"""

from .eviction import POLICIES, EvictionPolicy, SharedBudget, make_policy  # noqa: F401
from .latency import LatencyModel  # noqa: F401
from .trace import TRACE_SCHEMA_VERSION, TraceEvent, as_events, trace_oids  # noqa: F401
from .store import ObjectStore, PersistentObject  # noqa: F401
from .client import POSClient, Session  # noqa: F401
