"""granite-moe-1b-a400m [moe]: 32 experts, top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,  # per-expert intermediate
        vocab_size=49_155,
        norm="rmsnorm",
        mlp="swiglu",
        rope="default",
        rope_theta=10_000.0,
        n_experts=32,
        experts_per_token=8,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="granitemoe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab_size=128, n_experts=4, experts_per_token=2, head_dim=0,
    )
