"""qwen2-vl-2b [vlm]: M-RoPE, dynamic resolution — the vision frontend is a
STUB (input_specs provides precomputed patch embeddings + 3d position ids).
[arXiv:2409.12191; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab_size=151_936,
        norm="rmsnorm",
        mlp="swiglu",
        rope="mrope",  # multimodal rope: (t, h, w) sections over the head dim
        rope_theta=1_000_000.0,
        qkv_bias=True,
        embeds_input=True,  # patch/frame embeddings provided by the stub
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen2vl-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=128, head_dim=0,
    )
