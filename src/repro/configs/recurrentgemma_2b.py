"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, pattern
(rec, rec, attn); sub-quadratic -> runs long_500k. [arXiv:2402.19427; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        norm="rmsnorm",
        mlp="geglu",
        rope="default",
        rope_theta=10_000.0,
        block_pattern=("rec", "rec", "attn"),
        local_window=2048,
        lru_width=2560,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="rg-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=128, local_window=8, lru_width=64,
    )
