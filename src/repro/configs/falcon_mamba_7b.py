"""falcon-mamba-7b [ssm]: mamba1 architecture, attention-free, ssm_state=16;
sub-quadratic -> runs long_500k. [arXiv:2410.05355; unverified]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=1,  # unused by the SSM family
        n_kv_heads=1,
        head_dim=64,
        d_ff=0,  # the mamba block subsumes the MLP
        vocab_size=65_024,
        norm="rmsnorm",
        rope="none",
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="mamba-smoke", n_layers=2, d_model=64, vocab_size=128,
        ssm_state=4, dt_rank=8,
    )
