"""minitron-8b [dense]: pruned nemotron — layernorm, squared-ReLU MLP,
partial rotary, 256k vocab. [arXiv:2407.14679; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=16_384,
        vocab_size=256_000,
        norm="layernorm",
        mlp="relu2",  # nemotron squared relu
        rope="half",  # partial rotary (50%)
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="minitron-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab_size=256, head_dim=0,
    )
