"""chatglm3-6b [dense]: GQA kv=2, 2d-RoPE (rotary applied to half the head
dim), QKV bias. [arXiv:2406.12793; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13_696,
        vocab_size=65_024,
        norm="rmsnorm",
        mlp="swiglu",
        rope="half",  # ChatGLM's 2d rope: rotate only half of each head dim
        rope_theta=10_000.0,
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="chatglm3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab_size=128, head_dim=0,
    )
