from .base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    canonical_id,
    get_config,
    get_smoke_config,
    runnable_shapes,
)
