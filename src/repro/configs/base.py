"""Configuration system: model configs, shape configs, and the registry that
backs ``--arch <id>`` selection.

Every assigned architecture has one ``<id>.py`` in this package with the
exact published numbers; each also provides a ``smoke()`` reduction (same
family, tiny dims) used by the per-arch CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # components
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | gelu
    rope: str = "default"  # default | half | mrope | none | sinusoidal
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False  # per-head RMSNorm on q/k (qwen3)
    tie_embeddings: bool = False
    # modality frontend stub: model consumes precomputed embeddings
    embeds_input: bool = False

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # tokens per dispatch chunk: bounds the [T, E, C] dispatch tensors
    # (C scales with the chunk, so memory/flops stay O(chunk^2) per chunk)
    moe_chunk: int = 1024
    # dispatch implementation: "einsum" (one-hot matmul baseline) |
    # "scatter" (sort-free scatter dispatch — the §Perf hillclimb variant)
    moe_dispatch: str = "einsum"

    # SSM (mamba1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # hybrid (recurrentgemma): block pattern, local attention window
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    local_window: int = 2048
    lru_width: int = 0  # 0 -> d_model

    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_positions: int = 1500  # post-conv-stub audio frames

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""  # "" -> compute_dtype; e.g. "float8_e4m3fn"

    # sequence parallelism: shard residual activations on seq over `model`
    # between blocks (all-reduce -> reduce-scatter/all-gather pairs)
    sequence_parallel: bool = False

    # parallelism layout for train/prefill:
    #   "tp"   — Megatron tensor parallelism over `model` (+ DP over data)
    #   "fsdp" — fully-sharded data parallelism: batch over every mesh axis,
    #            weights sharded over (data, model) and gathered per layer;
    #            collective volume scales with weights, not activations
    parallelism: str = "tp"

    # attention implementation: naive | chunked (jnp online-softmax) —
    # Pallas kernels are selected separately by the launcher when on TPU
    attn_impl: str = "chunked"
    attn_chunk: int = 1024

    # remat policy for the layer scan: none | full | dots
    remat: str = "full"

    # logits/loss chunking over sequence (0 = no chunking)
    loss_chunk: int = 0

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "ssm" and self.dt_rank == 0:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))
        if self.family == "hybrid" and self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # -- derived sizes ------------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Total parameter count N (embedding included once)."""
        from repro.models.model import count_params_config

        return count_params_config(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_config

        return count_params_config(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape config (assigned input shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# long_500k requires sub-quadratic attention: only SSM/hybrid archs run it.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def runnable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
            continue  # full-attention arch: skipped per assignment (DESIGN.md)
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "whisper_large_v3",
    "chatglm3_6b",
    "yi_34b",
    "qwen1_5_4b",
    "minitron_8b",
    "qwen2_vl_2b",
    "recurrentgemma_2b",
    "qwen3_moe_30b_a3b",
    "granite_moe_1b_a400m",
    "falcon_mamba_7b",
)


def canonical_id(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    return mod.config()


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_id(arch)}")
    return mod.smoke()


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
