"""qwen1.5-4b [dense]: MHA (kv=heads) with QKV bias.
[hf:Qwen/Qwen1.5-0.5B family; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        d_ff=6912,
        vocab_size=151_936,
        norm="rmsnorm",
        mlp="swiglu",
        rope="default",
        rope_theta=5_000_000.0,
        qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen1.5-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab_size=128, head_dim=0,
    )
