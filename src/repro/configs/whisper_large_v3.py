"""whisper-large-v3 [audio]: encoder-decoder, conv frontend STUB
(input_specs provides 1500 precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,  # decoder layers
        enc_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51_866,
        norm="layernorm",
        mlp="gelu",
        rope="none",  # absolute positions (sinusoidal enc / learned dec)
        qkv_bias=True,
        attn_out_bias=True,
        mlp_bias=True,
        enc_positions=1500,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="whisper-smoke",
        n_layers=2,
        enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        enc_positions=16,
        head_dim=0,
    )
