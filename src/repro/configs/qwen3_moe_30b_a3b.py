"""qwen3-moe-30b-a3b [moe]: 128 experts, top-8, per-expert d_ff=768, QK-norm.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,  # per-expert intermediate
        vocab_size=151_936,
        norm="rmsnorm",
        mlp="swiglu",
        rope="default",
        rope_theta=1_000_000.0,
        n_experts=128,
        experts_per_token=8,
        qk_norm=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="qwen3moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=128, n_experts=8, experts_per_token=2,
    )
