"""yi-34b [dense]: llama-architecture GQA. [arXiv:2403.04652; hf]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b",
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20_480,
        vocab_size=64_000,
        norm="rmsnorm",
        mlp="swiglu",
        rope="default",
        rope_theta=5_000_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        name="yi-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=192, vocab_size=128, head_dim=0,
    )
