"""mamba_scan — the mamba1 selective-scan recurrence.

  h_t = dA_t * h_{t-1} + dBu_t          (h: [C, N] per step)
  y_t = h_t . C_t                       (contraction over the state dim N)

Grid: (C/bc, S/bs), sequence innermost; the [bc, N] state sits in VMEM
scratch while the per-step dA/dBu blocks stream past it.  N (the SSM state,
16 for falcon-mamba) rides in the lane dimension of the streamed blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(dA_ref, dBu_ref, c_ref, y_ref, h_ref, *, bs: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dA = dA_ref[...].astype(jnp.float32)  # [bs, bc, N]
    dBu = dBu_ref[...].astype(jnp.float32)  # [bs, bc, N]
    cm = c_ref[...].astype(jnp.float32)  # [bs, N]

    def step(t, carry):
        h, ys = carry
        h = dA[t] * h + dBu[t]  # [bc, N]
        y = jnp.sum(h * cm[t][None, :], axis=1)  # [bc]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y, t, 0)
        return (h, ys)

    h0 = h_ref[...]
    ys0 = jnp.zeros((bs, dA.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, bs, step, (h0, ys0))
    h_ref[...] = h
    y_ref[...] = ys.astype(y_ref.dtype)


def mamba_scan_kernel(dA, dBu, C, *, block_s: int = 128, block_c: int = 512,
                      interpret: bool = True):
    """dA, dBu [S, Ch, N]; C [S, N] -> y [S, Ch]."""
    S, Ch, N = dA.shape
    bs, bc = min(block_s, S), min(block_c, Ch)
    assert S % bs == 0 and Ch % bc == 0
    grid = (Ch // bc, S // bs)
    kernel = functools.partial(_mamba_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bc, N), lambda c, s: (s, c, 0)),
            pl.BlockSpec((bs, bc, N), lambda c, s: (s, c, 0)),
            pl.BlockSpec((bs, N), lambda c, s: (s, 0)),
        ],
        out_specs=pl.BlockSpec((bs, bc), lambda c, s: (s, c)),
        out_shape=jax.ShapeDtypeStruct((S, Ch), dA.dtype),
        scratch_shapes=[pltpu.VMEM((bc, N), jnp.float32)],
        interpret=interpret,
        name="mamba_scan",
    )(dA, dBu, C)
