"""Pallas TPU kernels for the compute hot-spots.

CAPre's contribution is a *prefetching schedule derived from static
analysis*.  On TPU the same idea lives at the kernel level: every kernel
here is a statically-scheduled DMA pipeline —

  * ``prefetch_gather``  — the CAPre poster child: the hint indices are
    **scalar-prefetch operands** feeding the BlockSpec index_map, so the
    Pallas pipeline issues the HBM->VMEM copies for the *predicted* rows
    ahead of compute (embedding rows, expert banks, KV pages);
  * ``flash_attention`` / ``decode_attention`` — KV blocks stream through
    VMEM ahead of the MXU (double-buffered by the Pallas grid pipeline);
  * ``rglru_scan`` / ``mamba_scan`` — sequential recurrences with the state
    held in VMEM scratch while sequence blocks stream past it.

Kernels target TPU (BlockSpec tiling aligned to 128-lane registers) and are
validated on CPU in interpret mode against the pure-jnp oracles in ref.py.
"""
