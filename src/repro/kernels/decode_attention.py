"""decode_attention — flash-decode: one query token against a long KV cache.

Grid: (batch*q_heads, S/bk).  The KV cache streams block-by-block through
VMEM while running max/sum/accumulator scratch carries the online softmax;
``kv_len`` arrives as a scalar-prefetch operand and blocks entirely past it
are skipped (``pl.when``) — the static schedule only *fetches* what the
access plan says will be read, CAPre-style.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                   bk: int, n_kv: int):
    j = pl.program_id(1)
    kv_len = len_ref[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * bk < kv_len)  # skip blocks entirely past the valid length
    def _compute():
        q = q_ref[...].astype(jnp.float32)  # [1, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D] (may arrive quantized)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * (1.0 / (q.shape[-1] ** 0.5))  # [1, bk]
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, kv_len, *, block_k: int = 512,
                            interpret: bool = True):
    """q [BH, D]; k, v [BKV, S, D]; kv_len scalar int32 -> [BH, D]."""
    BH, D = q.shape
    BKV, S, _ = k.shape
    G = BH // BKV
    bk = min(block_k, S)
    assert S % bk == 0
    n_kv = S // bk
    kernel = functools.partial(_decode_kernel, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(BH, n_kv),
            in_specs=[
                pl.BlockSpec((1, D), lambda h, j, len_ref: (h, 0)),
                pl.BlockSpec((1, bk, D), lambda h, j, len_ref, G=G: (h // G, j, 0)),
                pl.BlockSpec((1, bk, D), lambda h, j, len_ref, G=G: (h // G, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, D), lambda h, j, len_ref: (h, 0)),
            scratch_shapes=[
                pltpu.VMEM((1,), jnp.float32),
                pltpu.VMEM((1,), jnp.float32),
                pltpu.VMEM((1, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, D), q.dtype),
        interpret=interpret,
        name="decode_attention",
    )(jnp.asarray([kv_len], jnp.int32), q, k, v)
