"""jit'd public wrappers around the Pallas kernels.

Each wrapper accepts model-level layouts ([B, S, H, D] attention etc.),
folds them into the kernel layouts, picks interpret mode automatically
(interpret=True off-TPU so the kernels are validated on CPU), and exposes
the same signature as the pure-jnp oracle in ref.py.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_kernel
from .flash_attention import flash_attention_kernel
from .mamba_scan import mamba_scan_kernel
from .prefetch_gather import prefetch_gather_kernel
from .rglru_scan import rglru_scan_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _fold_q(q):
    B, Sq, H, D = q.shape
    return q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)


def _unfold_q(qf, B, H):
    BH, Sq, D = qf.shape
    return qf.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("causal", "q_offset", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, q_offset=0, block_q=128, block_k=128):
    """q [B, Sq, H, D]; k, v [B, Sk, KV, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    of = flash_attention_kernel(
        _fold_q(q), _fold_q(k), _fold_q(v), causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )
    return _unfold_q(of, B, H)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_trainable(q, k, v, causal=True, q_offset=0):
    """Differentiable flash attention: the Pallas forward kernel emits
    (o, lse); the backward runs the flash-attention-2 backward kernels
    (flash_attention_bwd.py) — scores/probs/ds never touch HBM in either
    direction."""
    return flash_attention(q, k, v, causal=causal, q_offset=q_offset)


def _fat_fwd(q, k, v, causal, q_offset):
    B, Sq, H, D = q.shape
    qf, kf, vf = _fold_q(q), _fold_q(k), _fold_q(v)
    of, lse = flash_attention_kernel(
        qf, kf, vf, causal=causal, q_offset=q_offset, interpret=_interpret(),
        with_lse=True,
    )
    return _unfold_q(of, B, H), (qf, kf, vf, of, lse, B, H)


def _fat_bwd(causal, q_offset, res, g):
    from .flash_attention_bwd import flash_attention_bwd_kernel

    qf, kf, vf, of, lse, B, H = res
    KV = kf.shape[0] // B
    G = H // KV
    dof = _fold_q(g)
    delta = jnp.sum(dof.astype(jnp.float32) * of.astype(jnp.float32), axis=-1)
    dq, dk_q, dv_q = flash_attention_bwd_kernel(
        qf, kf, vf, dof, lse, delta, causal=causal, q_offset=q_offset,
        interpret=_interpret(),
    )
    # reduce dk/dv over each kv head's query group (GQA)
    Sk, D = kf.shape[1], kf.shape[2]
    dk = dk_q.reshape(B, KV, G, Sk, D).sum(axis=2).reshape(B * KV, Sk, D)
    dv = dv_q.reshape(B, KV, G, Sk, D).sum(axis=2).reshape(B * KV, Sk, D)
    return _unfold_q(dq, B, H), _unfold_q(dk, B, KV), _unfold_q(dv, B, KV)


flash_attention_trainable.defvjp(_fat_fwd, _fat_bwd)


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, kv_len, *, block_k=512):
    """q [B, H, D]; k, v [B, S, KV, D]; kv_len scalar -> [B, H, D]."""
    B, H, D = q.shape
    KV = k.shape[2]
    qf = q.reshape(B * H, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, -1, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, -1, D)
    of = decode_attention_kernel(qf, kf, vf, kv_len, block_k=block_k, interpret=_interpret())
    return of.reshape(B, H, D)


@partial(jax.jit, static_argnames=("block_d",))
def prefetch_gather(table, idx, *, block_d=512):
    """table [N, D]; idx [B] -> [B, D] (D padded to a lane multiple)."""
    N, D = table.shape
    pad = (-D) % 128
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    bd = min(block_d, table.shape[1])
    while table.shape[1] % bd:
        bd //= 2
    out = prefetch_gather_kernel(table, idx, block_d=max(bd, 128), interpret=_interpret())
    return out[:, :D]


@partial(jax.jit, static_argnames=("block_s", "block_m"))
def rglru_scan(a, g, *, block_s=256, block_m=512):
    """a, g [B, S, W] -> y [B, S, W] (h_0 = 0): batch folded into channels."""
    B, S, W = a.shape
    af = a.transpose(1, 0, 2).reshape(S, B * W)
    gf = g.transpose(1, 0, 2).reshape(S, B * W)
    bm = min(block_m, B * W)
    while (B * W) % bm:
        bm //= 2
    bs = min(block_s, S)
    while S % bs:
        bs //= 2
    y = rglru_scan_kernel(af, gf, block_s=bs, block_m=max(1, bm), interpret=_interpret())
    return y.reshape(S, B, W).transpose(1, 0, 2)


@partial(jax.jit, static_argnames=("block_s", "block_c"))
def mamba_scan(dA, dBu, C, *, block_s=128, block_c=512):
    """dA, dBu [B, S, Ch, N]; C [B, S, N] -> y [B, S, Ch] (vmapped batch)."""
    bs = min(block_s, dA.shape[1])
    while dA.shape[1] % bs:
        bs //= 2
    bc = min(block_c, dA.shape[2])
    while dA.shape[2] % bc:
        bc //= 2
    fn = partial(
        mamba_scan_kernel, block_s=max(1, bs), block_c=max(1, bc), interpret=_interpret()
    )
    return jax.vmap(fn)(dA, dBu, C)
