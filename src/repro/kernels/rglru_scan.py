"""rglru_scan — the RG-LRU recurrence h_t = a_t * h_t-1 + g_t.

Grid: (M/bm, S/bs) with the sequence dimension innermost: for each channel
block the state lives in VMEM scratch while sequence blocks stream past it.
Inputs are the precomputed per-step decay ``a`` and gated input ``g``
(elementwise products are fused upstream); channels are the 128-lane axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, g_ref, y_ref, h_ref, *, bs: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)  # [bs, bm]
    g = g_ref[...].astype(jnp.float32)

    def step(t, carry):
        h, ys = carry
        h = a[t] * h + g[t]
        ys = jax.lax.dynamic_update_index_in_dim(ys, h, t, 0)
        return (h, ys)

    h0 = h_ref[...]
    ys0 = jnp.zeros(a.shape, jnp.float32)
    h, ys = jax.lax.fori_loop(0, bs, step, (h0, ys0))
    h_ref[...] = h
    y_ref[...] = ys.astype(y_ref.dtype)


def rglru_scan_kernel(a, g, *, block_s: int = 256, block_m: int = 512,
                      interpret: bool = True):
    """a, g [S, M] -> y [S, M] (h_0 = 0)."""
    S, M = a.shape
    bs, bm = min(block_s, S), min(block_m, M)
    assert S % bs == 0 and M % bm == 0
    grid = (M // bm, S // bs)  # sequence innermost (sequential)
    kernel = functools.partial(_rglru_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bm), lambda m, s: (s, m)),
            pl.BlockSpec((bs, bm), lambda m, s: (s, m)),
        ],
        out_specs=pl.BlockSpec((bs, bm), lambda m, s: (s, m)),
        out_shape=jax.ShapeDtypeStruct((S, M), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm,), jnp.float32)],
        interpret=interpret,
        name="rglru_scan",
    )(a, g)
