"""flash_attention — blocked causal attention with online softmax.

Grid: (batch*q_heads, Sq/bq, Sk/bk), KV innermost; the running max / sum /
accumulator live in VMEM scratch across the KV dimension, so KV blocks
stream HBM->VMEM through the Pallas pipeline (double-buffered) while the MXU
consumes the previous block.  GQA is handled without materializing repeated
KV heads: the KV BlockSpec index_map divides the query-head index by the
group size, so each KV head's blocks are fetched once per group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, causal: bool, q_offset: int, n_kv: int):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [bq, D]
    k = k_ref[0]  # [bk, D]
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (1.0 / (q.shape[-1] ** 0.5))
    if causal:
        qpos = q_offset + i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(l_safe)


def flash_attention_kernel(q, k, v, *, causal: bool = True, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True, with_lse: bool = False):
    """q [BH, Sq, D]; k, v [BKV, Sk, D] with BH % BKV == 0 -> [BH, Sq, D]
    (+ the log-sum-exp [BH, Sq] when ``with_lse`` — the flash-backward
    residual)."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    n_kv = Sk // bk
    grid = (BH, Sq // bq, n_kv)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, q_offset=q_offset, n_kv=n_kv
    )
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        name="flash_fwd",
    )(q, k, v)
    return (out, lse) if with_lse else out
