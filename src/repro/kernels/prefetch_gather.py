"""prefetch_gather — hint-driven row gather (the CAPre kernel).

The predicted row indices (the *prefetching hints* of the access plan) are
passed as **scalar-prefetch operands** (``pltpu.PrefetchScalarGridSpec``):
the BlockSpec ``index_map`` reads them to decide which HBM row block to DMA
into VMEM for each grid step, so the pipeline fetches the predicted rows
ahead of the compute that consumes them — the exact TPU analogue of the
paper's generated prefetch methods running ahead of the application.

Used for: embedding-row gather, MoE expert-bank staging, KV-page gather.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


def _gather_kernel(idx_ref, table_ref, out_ref):
    # the BlockSpec index_map already steered the DMA to row idx[b];
    # the body is a plain VMEM copy.
    del idx_ref
    out_ref[...] = table_ref[...]


def prefetch_gather_kernel(table, idx, *, block_d: int = 512, interpret: bool = True):
    """table [N, D] (D % 128 == 0), idx [B] int32 -> out [B, D]."""
    N, D = table.shape
    (B,) = idx.shape
    block_d = min(block_d, D)
    assert D % block_d == 0 and block_d % LANE == 0, (D, block_d)
    grid = (B, D // block_d)
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_d), lambda b, j, idx_ref: (idx_ref[b], j)),
            ],
            out_specs=pl.BlockSpec((1, block_d), lambda b, j, idx_ref: (b, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
        name="prefetch_gather",
    )(idx.astype(jnp.int32), table)
