"""flash_attention backward kernels (the flash-attention-2 backward pass).

Residuals from the forward: q, k, v, o, lse (= m + log l per query row).
The host precomputes delta = rowsum(do * o).  Two kernels:

  * ``_dkdv_kernel`` — grid (BH, n_kv, n_q): for each kv block, stream the
    q/do blocks past it, recompute p = exp(s - lse), accumulate
    dv += p^T do and dk += ds^T q in VMEM scratch;
  * ``_dq_kernel``   — grid (BH, n_q, n_kv): for each q block, stream the
    kv blocks, accumulate dq += ds k.

Scores/probs/ds never touch HBM.  GQA: both kernels run per QUERY head
(kv blocks fetched via the h // G index map); the wrapper sums dk/dv over
each kv head's query group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _recompute_p_ds(q, k, v, do, lse, delta, scale, causal, q_offset, qi, kj, bq, bk):
    """Shared recomputation: returns (p, ds), both [bq, bk] f32."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None]) * scale
    return p, ds


def _dkdv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                 dk_ref, dv_ref, dk_acc, dv_acc, *,
                 bq: int, bk: int, scale: float, causal: bool, q_offset: int, n_q: int):
    j = pl.program_id(1)  # kv block (outer)
    i = pl.program_id(2)  # q block (inner, accumulated)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    p, ds = _recompute_p_ds(
        q_ref[0], k_ref[0], v_ref[0], do_ref[0], lse_ref[0], delta_ref[0],
        scale, causal, q_offset, i, j, bq, bk,
    )
    dv_acc[...] += jax.lax.dot_general(
        p.astype(do_ref.dtype), do_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    dk_acc[...] += jax.lax.dot_general(
        ds.astype(q_ref.dtype), q_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_q - 1)
    def _flush():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_acc, *,
               bq: int, bk: int, scale: float, causal: bool, q_offset: int, n_kv: int):
    i = pl.program_id(1)  # q block (outer)
    j = pl.program_id(2)  # kv block (inner, accumulated)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    _, ds = _recompute_p_ds(
        q_ref[0], k_ref[0], v_ref[0], do_ref[0], lse_ref[0], delta_ref[0],
        scale, causal, q_offset, i, j, bq, bk,
    )
    dq_acc[...] += jax.lax.dot_general(
        ds.astype(k_ref.dtype), k_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == n_kv - 1)
    def _flush():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def flash_attention_bwd_kernel(q, k, v, do, lse, delta, *, causal: bool,
                               q_offset: int = 0, block_q: int = 128,
                               block_k: int = 128, interpret: bool = True):
    """q/do [BH, Sq, D]; k/v [BKV, Sk, D]; lse/delta [BH, Sq].

    Returns (dq [BH, Sq, D], dk_per_qhead [BH, Sk, D], dv_per_qhead
    [BH, Sk, D]) — the wrapper reduces dk/dv over each kv head's group."""
    BH, Sq, D = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    n_q, n_kv = Sq // bq, Sk // bk
    scale = 1.0 / (D**0.5)

    dkdv = pl.pallas_call(
        functools.partial(_dkdv_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
                          q_offset=q_offset, n_q=n_q),
        grid=(BH, n_kv, n_q),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, j, i: (h, i, 0)),  # q
            pl.BlockSpec((1, bk, D), lambda h, j, i, G=G: (h // G, j, 0)),  # k
            pl.BlockSpec((1, bk, D), lambda h, j, i, G=G: (h // G, j, 0)),  # v
            pl.BlockSpec((1, bq, D), lambda h, j, i: (h, i, 0)),  # do
            pl.BlockSpec((1, bq), lambda h, j, i: (h, i)),  # lse
            pl.BlockSpec((1, bq), lambda h, j, i: (h, i)),  # delta
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda h, j, i: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, j, i: (h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sk, D), k.dtype),
            jax.ShapeDtypeStruct((BH, Sk, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        interpret=interpret,
        name="flash_bwd_dkdv",
    )(q, k, v, do, lse, delta)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
                          q_offset=q_offset, n_kv=n_kv),
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),  # q
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),  # k
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),  # v
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),  # do
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),  # lse
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),  # delta
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
        name="flash_bwd_dq",
    )(q, k, v, do, lse, delta)
    return dq, dkdv[0], dkdv[1]
