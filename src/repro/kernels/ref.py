"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are tested against, on all shapes/dtypes)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """q [B, Sq, H, D]; k, v [B, Sk, KV, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q5 = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k, preferred_element_type=jnp.float32)
    s = s / (D**0.5)
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(k.shape[1])[None, :]
        s = jnp.where((kpos <= qpos)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, Sq, H, D)


def decode_attention_ref(q, k, v, kv_len):
    """q [B, H, D]; k, v [B, S, KV, D]; kv_len scalar -> [B, H, D]."""
    B, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    q5 = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", q5, k, preferred_element_type=jnp.float32) / (D**0.5)
    mask = jnp.arange(k.shape[1])[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return o.reshape(B, H, D)


def prefetch_gather_ref(table, idx):
    """table [N, D]; idx [B] -> [B, D]."""
    return jnp.take(table, idx, axis=0)


def rglru_scan_ref(a, g, h0=None):
    """a, g [S, M] -> y [S, M] with h_t = a_t * h_{t-1} + g_t, y_t = h_t."""
    S, M = a.shape
    if h0 is None:
        h0 = jnp.zeros((M,), jnp.float32)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t.astype(jnp.float32) * h + g_t.astype(jnp.float32)
        return h, h

    _, ys = jax.lax.scan(step, h0, (a, g))
    return ys.astype(a.dtype)


def mamba_scan_ref(dA, dBu, C, h0=None):
    """dA, dBu [S, Ch, N]; C [S, N] -> y [S, Ch] (h_t = dA*h + dBu;
    y = h . C_t)."""
    S, Ch, N = dA.shape
    if h0 is None:
        h0 = jnp.zeros((Ch, N), jnp.float32)

    def step(h, inp):
        dA_t, dBu_t, C_t = inp
        h = dA_t.astype(jnp.float32) * h + dBu_t.astype(jnp.float32)
        y = jnp.einsum("cn,n->c", h, C_t.astype(jnp.float32))
        return h, y

    _, ys = jax.lax.scan(step, h0, (dA, dBu, C))
    return ys.astype(dA.dtype)
