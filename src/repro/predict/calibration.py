"""Single source of truth for wall-vs-virtual latency calibration.

``benchmarks/calibrate_latency.py`` fits per-app scale factors mapping the
replay engine's simulated stall deltas onto measured wall-clock deltas and
writes them to ``artifacts/predict/calibration.csv``.  This module loads
them back so the REPLAY constants can be re-expressed in *calibrated wall
seconds* — replay output reports both, and ``LatencyModel.scaled`` builds a
calibrated model for anyone replaying in wall units directly (the ROADMAP
follow-on this closes: the fitted scales previously lived only in the CSV
and every consumer re-parsed or hard-coded them).
"""

from __future__ import annotations

import csv
import os
from dataclasses import dataclass, field
from typing import Optional

from repro.pos.latency import REPLAY, LatencyModel

DEFAULT_CALIBRATION_PATH = os.path.join("artifacts", "predict", "calibration.csv")


@dataclass
class Calibration:
    """Fitted simulated-seconds -> wall-seconds scale factors.  App keys
    match the evaluate catalog (``bank``, ``bank_write``, ``oo7``, ...);
    ``scale_for`` falls back to the global fit, then to 1.0 (uncalibrated:
    virtual seconds pass through unchanged)."""

    app_scales: dict[str, float] = field(default_factory=dict)
    global_scale: Optional[float] = None
    source: str = ""

    @property
    def fitted(self) -> bool:
        return bool(self.app_scales) or self.global_scale is not None

    def scale_for(self, app: str) -> float:
        scale = self.app_scales.get(app, self.global_scale)
        return scale if scale is not None else 1.0


def load_calibration(path: Optional[str] = None) -> Calibration:
    """Parse ``calibration.csv``.  A missing or unreadable file yields an
    unfitted (identity) calibration, never an error — benchmarks must run
    before the calibration artifact exists."""
    path = path or DEFAULT_CALIBRATION_PATH
    cal = Calibration(source=path)
    try:
        with open(path, newline="") as f:
            rows = list(csv.DictReader(f))
    except OSError:
        return cal
    for row in rows:
        app = row.get("app", "")
        try:
            if row.get("scale_app"):
                cal.app_scales[app] = float(row["scale_app"])
            if cal.global_scale is None and row.get("scale_global"):
                cal.global_scale = float(row["scale_global"])
        except ValueError:
            continue
    return cal


def calibrated_model(app: str, base: LatencyModel = REPLAY,
                     calibration: Optional[Calibration] = None) -> LatencyModel:
    """The replay latency model re-expressed in calibrated wall seconds for
    ``app`` (slot counts untouched; see ``LatencyModel.scaled``)."""
    if calibration is None:
        calibration = load_calibration()
    return base.scaled(calibration.scale_for(app))
