"""Predictor registry: ``mode`` strings resolve here (DESIGN.md section 3).

``Session(mode=...)`` and ``WeightStreamer(mode=...)`` used to branch on
hard-coded mode strings; both now resolve through this registry, so adding
a prediction strategy is one ``@register`` away from being runnable in the
POS interpreter, the weight streamer, the offline replay harness and the
benchmark driver.

Each entry couples up to two factories under one canonical name:

  * ``pos``    — a ``base.Predictor`` subclass for the object store
                 (``pos.client.Session``) and the offline replay harness;
  * ``stream`` — a ``stream.StreamPolicy`` subclass for the tensor-store
                 weight streamer (``runtime.prefetch.WeightStreamer``).

Aliases keep the historical spellings working: ``"capre"`` resolves to
``static-capre`` and ``"markov"`` to ``markov-miner``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional


@dataclass(frozen=True)
class PredictorSpec:
    name: str
    pos: Optional[type] = None
    stream: Optional[type] = None
    doc: str = ""


_REGISTRY: dict[str, PredictorSpec] = {}
_ALIASES: dict[str, str] = {}


def register(name: str, *, pos: Optional[type] = None, stream: Optional[type] = None,
             aliases: tuple[str, ...] = (), doc: str = "") -> None:
    """Register a prediction strategy under ``name`` (idempotent per name:
    re-registration replaces, which keeps module reloads harmless)."""
    spec = PredictorSpec(name=name, pos=pos, stream=stream, doc=doc)
    _REGISTRY[name] = spec
    if pos is not None:
        pos.name = name
    if stream is not None:
        stream.name = name
    for a in aliases:
        _ALIASES[a] = name


def canonical(mode: str) -> str:
    return _ALIASES.get(mode, mode)


def get(mode: str) -> PredictorSpec:
    key = canonical(mode)
    spec = _REGISTRY.get(key)
    if spec is None:
        raise KeyError(
            f"unknown prefetch mode {mode!r}; registered: {sorted(_REGISTRY)} "
            f"(aliases: {sorted(_ALIASES)})"
        )
    return spec


def available(kind: Optional[str] = None) -> list[str]:
    """Canonical names, optionally filtered to those supporting ``kind``
    ('pos' or 'stream')."""
    names = sorted(_REGISTRY)
    if kind is not None:
        names = [n for n in names if getattr(_REGISTRY[n], kind) is not None]
    return names


def make_pos_predictor(mode: str, **kwargs):
    spec = get(mode)
    if spec.pos is None:
        raise KeyError(f"mode {spec.name!r} has no object-store predictor")
    return spec.pos(**kwargs)


def make_stream_policy(mode: str, **kwargs):
    spec = get(mode)
    if spec.stream is None:
        raise KeyError(f"mode {spec.name!r} has no weight-stream policy")
    return spec.stream(**kwargs)
