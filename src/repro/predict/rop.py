"""Referenced-Objects Predictor behind the unified interface.

Schema-based prediction (paper sections 1-2): on every application-path
cache miss, eagerly fetch the object's referenced **single** associations
up to ``rop_depth`` levels — never collections.  The expansion depends only
on the schema, never on the running code, which is what makes it cheap
(no monitoring, no mined tables) and rigid (zero recall on collection-only
models like K-Means, the paper's Figure 14).

Online this preserves the historical ``Session(mode="rop")`` behavior
verbatim (miss listener -> BFS fan-out on the parallel pool).  Offline the
replay harness treats the *first* access to an oid as its cold-cache miss
and collects the same BFS frontier via ``peek``.
"""

from __future__ import annotations

from repro.core.rop import rop_referenced_fields

from .base import Predictor, table_bytes


class Rop(Predictor):
    def __init__(self, config=None):
        super().__init__()
        self.depth = getattr(config, "rop_depth", 1) if config is not None else 1
        self._fields: dict[str, list[tuple[str, str]]] = {}
        self._issued: set[int] = set()

    def attach(self, store, reg) -> None:
        super().attach(store, reg)
        app = reg.app
        self._fields = {cls: rop_referenced_fields(app, cls) for cls in app.classes}
        self.overhead.table_bytes = table_bytes(
            sum(len(v) for v in self._fields.values())
        )

    def bind(self, session) -> None:
        super().bind(session)
        self._listen(session.store, "miss_listener", lambda oid: self.on_miss(oid))

    # -- the BFS expansion (shared online/offline) --------------------------

    def _frontier(self, root_oid: int, fetch) -> list[int]:
        """BFS over single associations to ``self.depth``; ``fetch`` is
        applied to every referenced oid and the full frontier returned."""
        out: list[int] = []
        frontier = [root_oid]
        for _ in range(self.depth):
            nxt: list[int] = []
            for o in frontier:
                rec = self.store.record(o)
                for fld, _target in self._fields.get(rec.cls, ()):
                    ref = rec.fields.get(fld)
                    if ref is None:
                        continue
                    fetch(ref)
                    out.append(ref)
                    nxt.append(ref)
            frontier = nxt
            if not frontier:
                break
        return out

    def on_miss(self, oid: int) -> list[int]:
        if oid in self._issued:
            return []
        self._issued.add(oid)
        self.overhead.monitor_events += 1
        if self.session is not None:
            store = self.session.store
            runtime = self.session.runtime
            label = getattr(self.session, "label", "")
            if self._dispatch_mode() == "batch":
                # collect the frontier via peek (schema walk, no I/O), then
                # one deduped, need-ordered request per Data Service
                def bfs_batch(root_oid: int) -> None:
                    out = self._frontier(root_oid, lambda _ref: None)
                    self.overhead.predictions += len(out)
                    store.prefetch_batch(out, runtime=runtime,
                                         origin=f"rop:miss-{root_oid}",
                                         session=label)

                runtime.fan_out(bfs_batch, [oid])
                return []

            def bfs(root_oid: int) -> None:
                fetched = self._frontier(
                    root_oid,
                    lambda ref: store.prefetch_access(ref, session=label))
                self.overhead.predictions += len(fetched)

            runtime.fan_out(bfs, [oid])
            return []
        out = self._frontier(oid, lambda _ref: None)
        self.overhead.predictions += len(out)
        return out

    def on_access(self, oid: int, cls: str) -> list[int]:
        # offline replay only: a cold unbounded cache misses exactly on the
        # first access to each oid (online, the store's miss listener fires)
        if self.session is None:
            return self.on_miss(oid)
        return []
