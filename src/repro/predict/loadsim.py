"""Multi-tenant load simulation on the virtual clock (DESIGN.md §3.10).

``benchmarks/loadgen.py`` drives tens of concurrent live ``Session``s; this
module mirrors the same arrival processes on the deterministic virtual
clock so replay can sweep *hundreds* of tenants in seconds.  One shared
:class:`~repro.predict.evaluate.VirtualReplay` engine is time-multiplexed
across N tenants:

  * **shared contention state** — disks, caches (optionally one PR 4
    shared budget), in-flight loads, and the bounded prefetch-executor
    pool are ONE set of structures, so tenant A's prefetch flood queues
    tenant B's demand loads and evicts B's prefetched-but-unused lines
    (charged per-tenant via the engine's ``evicted_by_tenant`` owner map);
  * **per-tenant clock state** — each tenant owns its application clock,
    its current Data Service, and an exact stall histogram; the driver
    swaps them onto the engine around every event and interleaves tenants
    through a min-heap on virtual time (ties break on tenant index, so a
    run is a pure function of its seed);
  * **arrival processes** — ``closed`` (each tenant re-submits after an
    exponential think time) or ``poisson:RATE`` (open: job arrivals are a
    seeded Poisson process of aggregate RATE jobs/s split evenly across
    tenants; a tenant whose previous job overruns queues its next one);
  * **heavy-tailed service mix** — tenant k runs one of the catalog apps
    drawn with weight 1/rank (the cheap app dominates, the expensive tail
    is rare), seeded and deterministic;
  * **admission back-pressure** — the same decision rule as
    ``PrefetchRuntime.admit`` evaluated against the engine's modeled
    executor pool: with ``max_outstanding`` set, an emission arriving
    while that many workers are busy is shed unless its static priority
    clears ``admission_threshold``; sheds are counted per tenant.

Everything here is deterministic: two runs with the same arguments produce
byte-identical CSV rows (no wall-clock cells are written for virtual rows).
"""

from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.obs import Histogram
from repro.pos.client import POSClient, Session, SessionConfig
from repro.pos.eviction import DEFAULT_POLICY
from repro.pos.latency import REPLAY, LatencyModel
from repro.pos.trace import METHOD_ENTRY, WRITE, as_events

from . import make_pos_predictor
from .evaluate import VirtualReplay, _catalog

#: the committed ``artifacts/predict/loadgen.csv`` schema, shared by the
#: wall-clock harness (``benchmarks/loadgen.py``) and this simulator.
#: ``wall_s`` stays empty on virtual rows so a virtual sweep is
#: byte-reproducible; ``tenant="ALL"`` rows aggregate a whole configuration
#: and carry the fairness ratio.
LOADGEN_COLUMNS = [
    "clock", "tenants", "arrival", "mix", "dispatch", "mode",
    "cache_capacity", "shared_budget", "max_outstanding", "tenant", "app",
    "jobs", "ops", "stall_p50_s", "stall_p99_s", "stall_p999_s",
    "stall_mean_s", "stall_total_s", "evicted_before_use", "admission_shed",
    "fairness_ratio", "wall_s", "seed", "scenario", "failovers",
]

#: default service mix, cheapest-first: heavy-tailed weights 1/rank mean
#: most tenants run the light traversals and a long tail hits the big ones
#: (all five paper apps; bank contributes both its read and write
#: traversals, OO7's deep design tree is the rare expensive tail)
DEFAULT_MIX = ("bank", "wordcount", "kmeans", "bank_write", "pga", "oo7")


def parse_arrival(spec: str) -> tuple[str, float]:
    """``"closed"`` -> ("closed", 0.0); ``"poisson:RATE"`` -> ("poisson",
    RATE) with RATE in aggregate jobs/second."""
    if spec == "closed":
        return "closed", 0.0
    if spec.startswith("poisson:"):
        rate = float(spec.split(":", 1)[1])
        if rate <= 0:
            raise ValueError(f"poisson rate must be > 0, got {rate}")
        return "poisson", rate
    raise ValueError(f"unknown arrival spec {spec!r}; "
                     f"expected 'closed' or 'poisson:RATE'")


def heavy_tailed_weights(n: int) -> list[float]:
    return [1.0 / (i + 1) for i in range(n)]


@dataclass
class _Tenant:
    idx: int
    label: str
    app_key: str
    predictor: object
    events: list  # the app's recorded event stream (one job = one pass)
    jobs_left: int
    arrivals: list[float]  # open mode: precomputed job arrival times
    think_rng: random.Random
    t: float = 0.0
    cur_ds: Optional[int] = None
    pos: int = 0
    jobs_done: int = 0
    shed: int = 0
    hist: Histogram = field(
        default_factory=lambda: Histogram("tenant_stall_s", exact=True))


@dataclass
class TenantResult:
    label: str
    app: str
    jobs: int
    ops: int
    stall_p50_s: float
    stall_p99_s: float
    stall_p999_s: float
    stall_mean_s: float
    stall_total_s: float
    evicted_before_use: int
    admission_shed: int
    failovers: int = 0


@dataclass
class LoadsimReport:
    tenants: int
    arrival: str
    mix: str
    dispatch: str
    mode: str
    cache_capacity: int
    shared_budget: bool
    max_outstanding: int
    seed: int
    per_tenant: list[TenantResult]
    fairness_ratio: float
    total_stall_s: float
    evictions: int
    exec_delayed: int
    scenario: str = "no-fault"
    failovers: int = 0

    def rows(self) -> list[dict]:
        """CSV rows (LOADGEN_COLUMNS): one per tenant + one ALL aggregate."""
        base = {
            "clock": "virtual", "tenants": self.tenants,
            "arrival": self.arrival, "mix": self.mix,
            "dispatch": self.dispatch, "mode": self.mode,
            "cache_capacity": self.cache_capacity,
            "shared_budget": self.shared_budget,
            "max_outstanding": self.max_outstanding,
            "fairness_ratio": "", "wall_s": "", "seed": self.seed,
            "scenario": self.scenario,
        }
        out = []
        for tr in self.per_tenant:
            row = dict(base)
            row.update(
                tenant=tr.label, app=tr.app, jobs=tr.jobs, ops=tr.ops,
                stall_p50_s=round(tr.stall_p50_s, 9),
                stall_p99_s=round(tr.stall_p99_s, 9),
                stall_p999_s=round(tr.stall_p999_s, 9),
                stall_mean_s=round(tr.stall_mean_s, 9),
                stall_total_s=round(tr.stall_total_s, 9),
                evicted_before_use=tr.evicted_before_use,
                admission_shed=tr.admission_shed,
                failovers=tr.failovers,
            )
            out.append(row)
        agg = dict(base)
        ops = sum(tr.ops for tr in self.per_tenant)
        agg.update(
            tenant="ALL", app="mix",
            jobs=sum(tr.jobs for tr in self.per_tenant), ops=ops,
            stall_p50_s="", stall_p99_s="", stall_p999_s="",
            stall_mean_s=round(self.total_stall_s / max(1, ops), 9),
            stall_total_s=round(self.total_stall_s, 9),
            evicted_before_use=sum(tr.evicted_before_use
                                   for tr in self.per_tenant),
            admission_shed=sum(tr.admission_shed for tr in self.per_tenant),
            fairness_ratio=round(self.fairness_ratio, 4),
            failovers=self.failovers,
        )
        out.append(agg)
        return out


def write_loadgen_csv(path: str, rows: Sequence[dict],
                      append: bool = False) -> None:
    import csv
    import os

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    exists = append and os.path.exists(path)
    with open(path, "a" if exists else "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=LOADGEN_COLUMNS)
        if not exists:
            w.writeheader()
        for row in rows:
            w.writerow(row)


def _record_shared_catalog(app_keys: Sequence[str], n_services: int = 4
                           ) -> tuple[POSClient, dict[str, list]]:
    """One shared store holding every selected app's object graph (globally
    unique oids — same-app tenants share a database, the multi-tenant
    regime), plus one cold-cache recorded trace per app key.  Mutating
    workloads leave their updates in the shared store, exactly like live
    tenants would."""
    cat = _catalog()
    client = POSClient(n_services=n_services)
    roots: dict[str, int] = {}
    for key in app_keys:
        wl = cat[key]
        if wl.name not in client.logic_module.registered:
            client.register(wl.build_app())
        roots[key] = wl.populate(client.store)
    traces: dict[str, list] = {}
    for key in app_keys:
        wl = cat[key]
        client.store.reset_runtime_state()
        client.store.trace = []
        session = Session(client.store,
                          client.logic_module.registered[wl.name])
        try:
            wl.run_once(session, roots[key])
        finally:
            session.close()
        traces[key] = as_events(list(client.store.trace))
        client.store.trace = None
    return client, traces


def run_loadsim(
    tenants: int = 128,
    arrival: str = "closed",
    jobs: int = 1,
    mix: Sequence[str] = DEFAULT_MIX,
    seed: int = 0,
    mode: str = "capre",
    dispatch: str = "batch",
    cache_capacity: int = 128,
    shared_budget: bool = True,
    policy: str = DEFAULT_POLICY,
    max_outstanding: int = 0,
    admission_threshold: float = 0.0,
    latency: LatencyModel = REPLAY,
    executor_workers: int = 8,
    think_mean_s: float = 2e-3,
    n_services: int = 4,
    scenario: str = "no-fault",
    replication: int = 1,
    write_quorum: int = 1,
) -> LoadsimReport:
    """Simulate ``tenants`` concurrent sessions over one shared store on
    the virtual clock and return per-tenant tail-latency, interference and
    shed accounting.  Fully deterministic for a given argument set.

    A failure ``scenario`` (pos.latency.SCENARIO_NAMES) injects faults on
    the shared engine's clock — the heap driver dispatches events in global
    virtual-time order, so a crash/partition fires at one well-defined
    instant across all tenants and failovers attribute to the tenant whose
    access (or prefetch) tripped over it.  Fault times anchor on the fleet's
    stall-free floor (total think time), which is scenario- and
    quorum-invariant."""
    kind, rate = parse_arrival(arrival)
    mix = list(mix)
    client, traces = _record_shared_catalog(mix, n_services=n_services)
    store = client.store
    if replication != store.replication:
        store.rebuild_placement(store.placement_name,
                                replication=replication)

    rng = random.Random(seed)
    weights = heavy_tailed_weights(len(mix))
    assignment = rng.choices(mix, weights=weights, k=tenants)
    cat = _catalog()
    per_tenant_rate = rate / tenants if kind == "poisson" else 0.0

    sc = None
    if scenario and scenario != "no-fault":
        from repro.pos.latency import make_scenario

        total_access = jobs * sum(
            sum(1 for ev in traces[a] if ev.kind != METHOD_ENTRY)
            for a in assignment)
        sc = make_scenario(scenario, end_t=total_access * latency.think)
    engine = VirtualReplay(
        store, latency=latency, cache_capacity=cache_capacity,
        policy=policy, shared_budget=shared_budget, dispatch=dispatch,
        executor_workers=executor_workers, scenario=sc,
        write_quorum=write_quorum,
    )

    ts: list[_Tenant] = []
    for i in range(tenants):
        app_key = assignment[i]
        wl = cat[app_key]
        reg = client.logic_module.registered[wl.name]
        cfg = SessionConfig(mode=mode, dispatch=dispatch,
                            max_outstanding=max_outstanding,
                            admission_threshold=admission_threshold)
        predictor = make_pos_predictor(mode, config=cfg)
        predictor.attach(store, reg)
        arr_rng = random.Random((seed << 16) ^ (i * 2654435761 & 0xFFFFFFFF))
        arrivals: list[float] = []
        if kind == "poisson":
            t_arr = 0.0
            for _ in range(jobs):
                t_arr += arr_rng.expovariate(per_tenant_rate)
                arrivals.append(t_arr)
        tn = _Tenant(idx=i, label=f"t{i:03d}", app_key=app_key,
                     predictor=predictor, events=traces[app_key],
                     jobs_left=jobs, arrivals=arrivals, think_rng=arr_rng)
        tn.t = arrivals[0] if arrivals else 0.0
        ts.append(tn)

    heap = [(tn.t, tn.idx) for tn in ts if tn.jobs_left > 0 and tn.events]
    heapq.heapify(heap)

    while heap:
        _, idx = heapq.heappop(heap)
        tn = ts[idx]
        # install this tenant's clock view on the shared engine
        engine.t = tn.t
        engine.cur_ds = tn.cur_ds
        engine.stall_hist = tn.hist
        engine.active_tenant = tn.label
        ev = tn.events[tn.pos]
        pred = tn.predictor
        if ev.kind == METHOD_ENTRY:
            out = pred.on_method_entry(ev.method_key, ev.oid)
            rfo_oids, priorities = pred.take_emission_meta()
            _emit(engine, tn, out, f"{pred.name}:{ev.method_key}",
                  rfo_oids, priorities, max_outstanding, admission_threshold)
        elif ev.kind == WRITE:
            engine.write(ev.oid)
            out = pred.on_write(ev.oid, store.cls_of(ev.oid))
            rfo_oids, priorities = pred.take_emission_meta()
            _emit(engine, tn, out, f"{pred.name}:on_access",
                  rfo_oids, priorities, max_outstanding, admission_threshold)
        else:
            engine.access(ev.oid)
            out = pred.on_access(ev.oid, store.cls_of(ev.oid))
            rfo_oids, priorities = pred.take_emission_meta()
            _emit(engine, tn, out, f"{pred.name}:on_access",
                  rfo_oids, priorities, max_outstanding, admission_threshold)
        # read the tenant's clock view back off the engine
        tn.t = engine.t
        tn.cur_ds = engine.cur_ds
        tn.pos += 1
        if tn.pos >= len(tn.events):
            # job complete
            tn.pos = 0
            tn.jobs_done += 1
            tn.jobs_left -= 1
            if tn.jobs_left <= 0:
                continue
            if kind == "closed":
                tn.t += tn.think_rng.expovariate(1.0 / think_mean_s)
            else:
                # open: the next job was already scheduled to arrive; a
                # tenant whose previous job overran starts it late (queued)
                tn.t = max(tn.t, tn.arrivals[tn.jobs_done])
            # a new job starts cold from the root's Data Service
            tn.cur_ds = None
        heapq.heappush(heap, (tn.t, tn.idx))

    engine.active_tenant = ""
    per = []
    means = []
    for tn in ts:
        p50, p99, p999 = tn.hist.percentiles((0.5, 0.99, 0.999))
        ops = tn.hist.count
        mean = tn.hist.sum / ops if ops else 0.0
        if ops:
            means.append(mean)
        per.append(TenantResult(
            label=tn.label, app=tn.app_key, jobs=tn.jobs_done, ops=ops,
            stall_p50_s=p50 or 0.0, stall_p99_s=p99 or 0.0,
            stall_p999_s=p999 or 0.0, stall_mean_s=mean,
            stall_total_s=tn.hist.sum,
            evicted_before_use=engine.evicted_by_tenant.get(tn.label, 0),
            admission_shed=tn.shed,
            failovers=engine.failovers_by_tenant.get(tn.label, 0),
        ))
    fairness = (max(means) / max(min(means), 1e-12)) if means else 0.0
    return LoadsimReport(
        tenants=tenants, arrival=arrival, mix="+".join(mix),
        dispatch=dispatch, mode=mode, cache_capacity=cache_capacity,
        shared_budget=engine.shared_budget, max_outstanding=max_outstanding,
        seed=seed, per_tenant=per, fairness_ratio=fairness,
        total_stall_s=engine.stall_seconds, evictions=engine.evictions,
        exec_delayed=engine.exec_delayed,
        scenario=scenario or "no-fault", failovers=engine.failovers,
    )


def _emit(engine: VirtualReplay, tn: _Tenant, oids, origin: str,
          rfo_oids: frozenset, priorities: dict,
          max_outstanding: int, admission_threshold: float) -> None:
    """Dispatch a tenant's emission through the shared engine, mirroring
    ``PrefetchRuntime.admit``: with ``max_outstanding`` armed, an emission
    arriving while that many modeled executor workers are busy is shed
    unless its best static priority clears the threshold."""
    if not oids:
        return
    if max_outstanding:
        busy = sum(1 for s in engine._exec_slots if s > tn.t)
        best = max(priorities.values()) if priorities else 0.0
        if busy >= max_outstanding and best < admission_threshold:
            tn.shed += 1
            return
    engine.predict(oids, origin=origin, rfo=rfo_oids,
                   priorities=priorities or None)
