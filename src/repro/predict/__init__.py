"""Pluggable prefetch-prediction subsystem (DESIGN.md section 3).

One registry hosts every prediction strategy the paper compares (and the
ones it only argues against):

  ================  =============================  =========================
  name              object store (Session)          tensor store (streamer)
  ================  =============================  =========================
  static-capre      hints + injected closures       plan-driven k-ahead
  (alias: capre)    (zero monitoring)
  rop               miss-driven BFS over single     next groups in tree
                    associations                    order, no collections
  markov-miner      order-k trace mining            group-transition mining
  (alias: markov)   (Palpatine-style)
  hybrid            static collections + mined      plan collections + mined
                    single chains (GrASP-style)     transitions
  ================  =============================  =========================

``pos.client.Session(mode=...)`` and ``runtime.prefetch.WeightStreamer
(mode=...)`` both resolve their mode strings here; ``predict.evaluate``
replays recorded traces against every registered predictor offline.
"""

from .base import Overhead, Predictor
from .hybrid import Hybrid
from .markov import MarkovMiner
from .registry import (
    available,
    canonical,
    get,
    make_pos_predictor,
    make_stream_policy,
    register,
)
from .rop import Rop
from .static_capre import StaticCapre
from .stream import CapreStream, HybridStream, MarkovStream, RopStream, StreamPolicy

register(
    "static-capre",
    pos=StaticCapre,
    stream=CapreStream,
    aliases=("capre",),
    doc="code-analysis hints derived at registration time; zero monitoring",
)
register(
    "rop",
    pos=Rop,
    stream=RopStream,
    doc="schema-based referenced-objects expansion (single associations only)",
)
register(
    "markov-miner",
    pos=MarkovMiner,
    stream=MarkovStream,
    aliases=("markov",),
    doc="order-k frequent-sequence mining over recorded traces (monitoring)",
)
register(
    "hybrid",
    pos=Hybrid,
    stream=HybridStream,
    doc="static hints for collections + trace-mined single-association chains",
)

__all__ = [
    "Overhead",
    "Predictor",
    "StaticCapre",
    "Rop",
    "MarkovMiner",
    "Hybrid",
    "StreamPolicy",
    "CapreStream",
    "RopStream",
    "MarkovStream",
    "HybridStream",
    "register",
    "get",
    "canonical",
    "available",
    "make_pos_predictor",
    "make_stream_policy",
]
