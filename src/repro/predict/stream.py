"""Stream policies: the prediction strategies on the tensor store.

``runtime.prefetch.WeightStreamer`` is the weight-streaming analogue of the
POS session (DESIGN.md section 2); its ``mode`` string resolves through the
same registry as ``Session``'s.  A policy's single entry point mirrors the
injected scheduling point: it is called when the compute frontier enters a
group and decides which *future* groups to fetch.

  * ``capre``  — follows the statically derived PrefetchPlan ``k_ahead``
    groups ahead, collections included (zero runtime monitoring);
  * ``rop``    — schema-only: the next ``rop_depth`` groups in tree order,
    never collections (it cannot know a scan consumes all layers);
  * ``markov-miner`` — plan-blind: mines group-transition counts from a
    recorded group log (``WeightStreamer.group_log`` of a prior run) and
    follows the most likely successor chain;
  * ``hybrid`` — static plan for collection groups (stream them ahead like
    capre) + the mined transitions for everything else.
"""

from __future__ import annotations

import time
from collections import Counter
from typing import Sequence


class StreamPolicy:
    name = "?"

    def warm(self, group_trace: Sequence[int]) -> None:
        """Consume a recorded group-entry log from a prior run (miners)."""

    def on_group_start(self, streamer, group_index: int) -> None:
        raise NotImplementedError


class CapreStream(StreamPolicy):
    def on_group_start(self, streamer, group_index: int) -> None:
        groups = streamer._groups
        hi = min(group_index + 1 + streamer.k_ahead, len(groups))
        for gi in range(group_index + 1, hi):
            # one batched dispatch per plan group (deduped against cache +
            # in-flight in one snapshot) instead of one pool task per record
            streamer.fetch_group([rec.path for rec in groups[gi]])


class RopStream(StreamPolicy):
    def on_group_start(self, streamer, group_index: int) -> None:
        groups = streamer._groups
        hi = min(group_index + 1 + streamer.rop_depth, len(groups))
        for gi in range(group_index + 1, hi):
            # ROP cannot prefetch collections (section 2): skip stacked
            # layer groups entirely
            streamer.fetch_group(
                [rec.path for rec in groups[gi] if not rec.collection]
            )


class MarkovStream(StreamPolicy):
    """Order-1 transition mining over group indices.  Unwarmed it fetches
    nothing — the honest cold-start of a monitoring-based approach."""

    def __init__(self):
        self._table: dict[int, Counter] = {}
        self.train_seconds = 0.0

    def warm(self, group_trace: Sequence[int]) -> None:
        t0 = time.perf_counter()
        trace = list(group_trace)
        for a, b in zip(trace, trace[1:]):
            self._table.setdefault(a, Counter())[b] += 1
        self.train_seconds += time.perf_counter() - t0

    def on_group_start(self, streamer, group_index: int) -> None:
        groups = streamer._groups
        cur, fetched = group_index, 0
        while fetched < streamer.k_ahead:
            counts = self._table.get(cur)
            if not counts:
                break
            nxt = counts.most_common(1)[0][0]
            if not (0 <= nxt < len(groups)) or nxt == cur:
                break
            streamer.fetch_group([rec.path for rec in groups[nxt]])
            fetched += 1
            cur = nxt


class HybridStream(MarkovStream):
    def on_group_start(self, streamer, group_index: int) -> None:
        # static part: stream collection groups ahead (exact from the plan)
        groups = streamer._groups
        hi = min(group_index + 1 + streamer.k_ahead, len(groups))
        for gi in range(group_index + 1, hi):
            streamer.fetch_group(
                [rec.path for rec in groups[gi] if rec.collection]
            )
        # learned part: mined transitions cover the non-collection groups
        super().on_group_start(streamer, group_index)
