"""The unified prediction interface (DESIGN.md section 3).

CAPre's argument (paper sections 1-2) is a three-way comparison:

  * **schema-based** prediction (ROP) — cheap but rigid: the same expansion
    regardless of the running code;
  * **monitoring-based** prediction (Palpatine-style sequence mining) —
    adaptive but pays a *runtime overhead*: every access is observed, and
    the mined tables occupy memory;
  * **code-analysis-based** prediction (CAPre) — derived entirely at
    registration time, zero runtime monitoring.

The repo originally hard-wired the first and third into ``pos.client`` and
``runtime.prefetch``; this module defines the common ``Predictor`` surface
that all strategies implement so they can be compared head-to-head, and an
``Overhead`` ledger so the memory/CPU cost the paper attributes to the
monitoring family is *measured*, not asserted.

A predictor serves two hosts:

  * **online** — bound to a live ``pos.client.Session``: it installs the
    store hooks it needs (``miss_listener`` for ROP, ``access_listener``
    for the miners) and schedules real ``prefetch_access`` work on the
    session's background runtime;
  * **offline** — driven by ``predict.evaluate`` replaying a recorded
    trace: the same ``on_access``/``on_method_entry`` entry points return
    the predicted oids instead of scheduling loads, so precision/recall
    can be computed without a store in the loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

# Rough per-entry cost of a Python dict slot holding small ints; used to
# charge mined tables a realistic resident size (the paper's "memory
# overhead to store data structures of monitored accesses").
_TABLE_ENTRY_BYTES = 96


@dataclass
class Overhead:
    """The runtime cost a prediction strategy pays (beyond the prefetch
    I/O itself, which every strategy pays and the store already meters)."""

    table_bytes: int = 0  # resident size of mined/derived tables
    monitor_events: int = 0  # accesses observed at runtime (monitoring tax)
    train_seconds: float = 0.0  # offline mining / analysis wall time
    predictions: int = 0  # oids emitted (prefetch pressure)
    # timeliness (filled by the virtual-clock replay engine): a prediction
    # only helps if its load *completes* before the access needs it
    late_predictions: int = 0  # predicted, but load still in flight (or queued) at need
    evicted_before_use: int = 0  # prefetched loads evicted before any access
    hidden_seconds: float = 0.0  # disk seconds removed from the app critical path
    protected_evictions: int = 0  # evictions where the policy spared a pending prefetch
    # dispatch accounting (filled by the replay engine; the live store keeps
    # the same pair per Data Service): how many executor submissions the
    # prediction stream cost, and how many requested oids were suppressed
    # before submission because they were already cached / in flight
    batch_dispatches: int = 0
    dedup_suppressed: int = 0
    # static-optimizer accounting (core.opt annotations): prefetches issued
    # read-for-ownership (dirty-allocated ahead of a known update site), and
    # collection expansions clipped to their static prefix bound
    rfo_prefetches: int = 0
    truncated_hints: int = 0
    # instrumentation self-accounting (repro.obs): what the observability
    # layer itself cost this run — charged here so CAPre's zero-overhead
    # claim stays falsifiable *with the instruments attached*
    obs_seconds: float = 0.0
    obs_events: int = 0

    def snapshot(self) -> dict:
        return dict(self.__dict__)


def table_bytes(n_entries: int, entry_bytes: int = _TABLE_ENTRY_BYTES) -> int:
    return n_entries * entry_bytes


class Predictor:
    """Base class for all prefetch predictors.

    Lifecycle: construct -> (optionally) ``warm(trace)`` -> either
    ``bind(session)`` for online use or plain ``on_*`` calls for offline
    replay.  Subclasses override the ``on_*`` hooks; both must be
    side-effect-free when ``self.session is None`` (offline mode) and may
    schedule real prefetches when bound.
    """

    #: registry name (set by the @register decorator)
    name: str = "?"

    def __init__(self) -> None:
        self.session = None  # live pos.client.Session when bound
        self.store = None  # ObjectStore (bound or attached for replay)
        self.reg = None  # pos.client.RegisteredApp (schema + analysis)
        self.overhead = Overhead()
        self._installed_listeners: list[tuple[str, object]] = []
        # offline emission metadata (static-optimizer signals) accumulated
        # by _emit between take_emission_meta() calls — the replay harness
        # reads it so the virtual clock sees the same rfo/priority stream
        # the live dispatch path gets
        self._pending_rfo: set[int] = set()
        self._pending_priorities: dict[int, float] = {}

    # -- lifecycle ---------------------------------------------------------

    def warm(self, trace: Sequence) -> None:
        """Consume a recorded trace (``ObjectStore.trace``: schema-v2
        ``TraceEvent`` records, or a legacy bare-oid list) before prediction
        starts.  Static strategies ignore it; trace miners build their
        tables here and charge ``overhead.train_seconds`` /
        ``overhead.table_bytes``."""

    def attach(self, store, reg) -> None:
        """Give the predictor its schema/analysis context without a live
        session — what the offline replay harness uses.  Subclasses build
        their derived structures here."""
        self.store = store
        self.reg = reg

    def bind(self, session) -> None:
        """Attach to a live Session: install whatever store listeners this
        strategy needs.  The default installs nothing."""
        self.session = session
        self.attach(session.store, session.reg)

    def _listen(self, store, attr: str, fn) -> None:
        """Install a store listener and remember it as ours, so unbind can
        remove exactly what this predictor installed — and nothing another
        session's predictor owns.  The callable is tagged with its owning
        predictor so ``Session.close`` can refuse to resurrect a hook whose
        predictor has since unbound (``fn`` must accept attributes — pass a
        lambda, not a bound method)."""
        fn.predictor = self
        setattr(store, attr, fn)
        self._installed_listeners.append((attr, fn))

    def unbind(self) -> None:
        """Detach from the session (Session.close): remove only the
        listeners this predictor installed (if still in place — a later
        session may have legitimately replaced them)."""
        if self.session is not None:
            store = self.session.store
            for attr, fn in self._installed_listeners:
                if getattr(store, attr) is fn:
                    setattr(store, attr, None)
        self._installed_listeners = []
        self.session = None

    # -- prediction entry points ------------------------------------------

    def on_method_entry(self, method_key: str, this_oid: int) -> list[int]:
        """Called when the application enters a registered method (the
        paper's injected scheduling point).  Returns the oids predicted at
        this point; when bound, also schedules their prefetch."""
        return []

    def on_access(self, oid: int, cls: str) -> list[int]:
        """Called on every application-path object access (the monitoring
        hook).  Returns the oids predicted to be accessed next; when
        bound, also schedules their prefetch."""
        return []

    def on_write(self, oid: int, cls: str) -> list[int]:
        """Called on every application-path field update.  Writes are
        demand accesses (write-allocate), so by default they feed the same
        monitoring hook as reads — Palpatine-style miners observe the full
        get/put stream.  Override to treat updates differently."""
        return self.on_access(oid, cls)

    def on_miss(self, oid: int) -> list[int]:
        """Called on application-path cache misses only (the ROP hook)."""
        return []

    # -- shared helpers ----------------------------------------------------

    def _dispatch_mode(self) -> str:
        """The bound session's dispatch granularity ("batch" unless the
        session opted into the legacy per-oid fan-out)."""
        cfg = self.session.config if self.session is not None else None
        return getattr(cfg, "dispatch", "batch")

    def _emit(self, oids: Iterable[int], context: str = "",
              rfo: frozenset = frozenset(),
              priorities: Optional[dict] = None) -> list[int]:
        """Account predictions; when bound, dispatch their loads on the
        session's background runtime — batched per Data Service by default,
        or one pool task per oid in "per-oid" mode.  ``context`` names the
        point in the program that triggered the prediction (method key /
        hint node); spans carry it as ``origin = "<predictor>:<context>"``.

        ``rfo`` oids dirty-allocate on landing and ``priorities``
        (oid -> static dispatch priority) orders/gates batched dispatch —
        the static-optimizer signals (core.opt).  Offline (no session) the
        metadata accumulates for ``take_emission_meta``."""
        out = [o for o in oids]
        self.overhead.predictions += len(out)
        if not out:
            return out
        if self.session is None:
            self._pending_rfo.update(rfo)
            if priorities:
                self._pending_priorities.update(priorities)
            return out
        cfg = self.session.config
        if not getattr(cfg, "rfo", True):
            rfo = frozenset()
        store = self.session.store
        origin = f"{self.name}:{context}" if context else self.name
        # per-call span attribution: the session's label travels with every
        # dispatch instead of living on shared tracer state, so concurrent
        # tenants' spans interleave correctly
        label = getattr(self.session, "label", "")
        if self._dispatch_mode() == "batch":
            store.prefetch_batch(out, runtime=self.session.runtime,
                                 origin=origin, rfo=rfo,
                                 priorities=priorities or None,
                                 session=label)
        else:
            self.session.runtime.fan_out(
                lambda oid: store.prefetch_access(oid, origin=origin,
                                                  rfo=oid in rfo,
                                                  session=label), out
            )
        return out

    def take_emission_meta(self) -> tuple[frozenset, dict]:
        """Drain the static-optimizer metadata accumulated by offline
        ``_emit`` calls since the last drain: ``(rfo_oids, priorities)``.
        The replay harness calls this after each ``on_*`` hook so the
        virtual dispatch sees the same signals the live path gets."""
        rfo = frozenset(self._pending_rfo)
        priorities = dict(self._pending_priorities)
        self._pending_rfo.clear()
        self._pending_priorities.clear()
        return rfo, priorities
