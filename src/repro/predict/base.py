"""The unified prediction interface (DESIGN.md section 3).

CAPre's argument (paper sections 1-2) is a three-way comparison:

  * **schema-based** prediction (ROP) — cheap but rigid: the same expansion
    regardless of the running code;
  * **monitoring-based** prediction (Palpatine-style sequence mining) —
    adaptive but pays a *runtime overhead*: every access is observed, and
    the mined tables occupy memory;
  * **code-analysis-based** prediction (CAPre) — derived entirely at
    registration time, zero runtime monitoring.

The repo originally hard-wired the first and third into ``pos.client`` and
``runtime.prefetch``; this module defines the common ``Predictor`` surface
that all strategies implement so they can be compared head-to-head, and an
``Overhead`` ledger so the memory/CPU cost the paper attributes to the
monitoring family is *measured*, not asserted.

A predictor serves two hosts:

  * **online** — bound to a live ``pos.client.Session``: it installs the
    store hooks it needs (``miss_listener`` for ROP, ``access_listener``
    for the miners) and schedules real ``prefetch_access`` work on the
    session's background runtime;
  * **offline** — driven by ``predict.evaluate`` replaying a recorded
    trace: the same ``on_access``/``on_method_entry`` entry points return
    the predicted oids instead of scheduling loads, so precision/recall
    can be computed without a store in the loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

# Rough per-entry cost of a Python dict slot holding small ints; used to
# charge mined tables a realistic resident size (the paper's "memory
# overhead to store data structures of monitored accesses").
_TABLE_ENTRY_BYTES = 96


@dataclass
class Overhead:
    """The runtime cost a prediction strategy pays (beyond the prefetch
    I/O itself, which every strategy pays and the store already meters)."""

    table_bytes: int = 0  # resident size of mined/derived tables
    monitor_events: int = 0  # accesses observed at runtime (monitoring tax)
    train_seconds: float = 0.0  # offline mining / analysis wall time
    predictions: int = 0  # oids emitted (prefetch pressure)
    # timeliness (filled by the virtual-clock replay engine): a prediction
    # only helps if its load *completes* before the access needs it
    late_predictions: int = 0  # predicted, but load still in flight (or queued) at need
    evicted_before_use: int = 0  # prefetched loads evicted before any access
    hidden_seconds: float = 0.0  # disk seconds removed from the app critical path

    def snapshot(self) -> dict:
        return dict(self.__dict__)


def table_bytes(n_entries: int, entry_bytes: int = _TABLE_ENTRY_BYTES) -> int:
    return n_entries * entry_bytes


class Predictor:
    """Base class for all prefetch predictors.

    Lifecycle: construct -> (optionally) ``warm(trace)`` -> either
    ``bind(session)`` for online use or plain ``on_*`` calls for offline
    replay.  Subclasses override the ``on_*`` hooks; both must be
    side-effect-free when ``self.session is None`` (offline mode) and may
    schedule real prefetches when bound.
    """

    #: registry name (set by the @register decorator)
    name: str = "?"

    def __init__(self) -> None:
        self.session = None  # live pos.client.Session when bound
        self.store = None  # ObjectStore (bound or attached for replay)
        self.reg = None  # pos.client.RegisteredApp (schema + analysis)
        self.overhead = Overhead()

    # -- lifecycle ---------------------------------------------------------

    def warm(self, trace: Sequence[int]) -> None:
        """Consume a recorded access trace (``ObjectStore.trace``) before
        prediction starts.  Static strategies ignore it; trace miners build
        their tables here and charge ``overhead.train_seconds`` /
        ``overhead.table_bytes``."""

    def attach(self, store, reg) -> None:
        """Give the predictor its schema/analysis context without a live
        session — what the offline replay harness uses.  Subclasses build
        their derived structures here."""
        self.store = store
        self.reg = reg

    def bind(self, session) -> None:
        """Attach to a live Session: install whatever store listeners this
        strategy needs.  The default installs nothing."""
        self.session = session
        self.attach(session.store, session.reg)

    def unbind(self) -> None:
        """Detach from the session (Session.close)."""
        if self.session is not None:
            store = self.session.store
            if store.miss_listener is not None:
                store.miss_listener = None
            if store.access_listener is not None:
                store.access_listener = None
        self.session = None

    # -- prediction entry points ------------------------------------------

    def on_method_entry(self, method_key: str, this_oid: int) -> list[int]:
        """Called when the application enters a registered method (the
        paper's injected scheduling point).  Returns the oids predicted at
        this point; when bound, also schedules their prefetch."""
        return []

    def on_access(self, oid: int, cls: str) -> list[int]:
        """Called on every application-path object access (the monitoring
        hook).  Returns the oids predicted to be accessed next; when
        bound, also schedules their prefetch."""
        return []

    def on_miss(self, oid: int) -> list[int]:
        """Called on application-path cache misses only (the ROP hook)."""
        return []

    # -- shared helpers ----------------------------------------------------

    def _emit(self, oids: Iterable[int]) -> list[int]:
        """Account predictions; when bound, fan their loads out on the
        session's background runtime."""
        out = [o for o in oids]
        self.overhead.predictions += len(out)
        if out and self.session is not None:
            store = self.session.store
            self.session.runtime.fan_out(store.prefetch_access, out)
        return out
