"""CAPre's code-analysis predictor behind the unified interface.

This is the paper's own strategy: everything is derived at registration
time (``core.hints`` builds PH_m, ``core.injection`` generates the prefetch
closures), so the runtime pays **no monitoring** — ``on_access`` is a no-op
and the only scheduling point is method entry, exactly the injected
``prefetchingExecutor.submit`` of Listing 5.

Online it preserves the historical ``Session(mode="capre")`` behavior
verbatim: the generated closure runs on the session's single-thread
background executor and fans collection hints out on the parallel pool.
Offline (no session) the same hint trees are expanded over the store
snapshot via ``peek`` so the replay harness gets the predicted oid set
without paying I/O.
"""

from __future__ import annotations

from repro.core import lang
from repro.core.injection import _HintTree, build_hint_tree

from .base import Predictor, table_bytes


def expand_hint_tree(store, root_oid: int, tree: _HintTree) -> list[int]:
    """The oids a generated prefetch method would load for ``root_oid``,
    computed over the current store contents without cost accounting."""
    out: list[int] = []

    def visit(oid: int, node: _HintTree) -> None:
        out.append(oid)
        rec = store.peek(oid)
        for child in node.children.values():
            ref = rec.fields.get(child.fld)
            if ref is None:
                continue
            if child.card == lang.COLLECTION:
                for e in list(ref):
                    visit(e, child)
            else:
                visit(ref, child)

    visit(root_oid, tree)
    return out


class _CountingStore:
    """Thin store proxy charging every ``prefetch_access`` to a predictor's
    ``Overhead`` ledger — the generated prefetch closures cannot do it
    themselves."""

    def __init__(self, store, overhead):
        self._store = store
        self._overhead = overhead

    def prefetch_access(self, oid: int):
        self._overhead.predictions += 1
        return self._store.prefetch_access(oid)

    def __getattr__(self, name):
        return getattr(self._store, name)


class StaticCapre(Predictor):
    """Hint-driven prefetching — zero runtime monitoring."""

    def __init__(self, config=None, hint_filter=None):
        super().__init__()
        self.config = config
        self.hint_filter = hint_filter  # optional predicate over Hint
        self._methods: dict[str, object] = {}
        self._trees: dict[str, _HintTree] = {}

    def attach(self, store, reg) -> None:
        super().attach(store, reg)
        if self.hint_filter is None:
            self._methods = dict(reg.prefetch_methods)
            hints = reg.report.hints
        else:
            from repro.core.injection import generate_prefetch_method

            hints = {
                k: tuple(h for h in hs if self.hint_filter(h))
                for k, hs in reg.report.hints.items()
            }
            self._methods = {}
            for k, hs in hints.items():
                fn = generate_prefetch_method(hs)
                if fn is not None:
                    self._methods[k] = fn
        self._trees = {k: build_hint_tree(hs) for k, hs in hints.items() if hs}
        # the analysis is this strategy's entire training cost
        self.overhead.train_seconds += reg.analysis_time_s
        self.overhead.table_bytes = table_bytes(
            sum(len(hs) for hs in hints.values())
        )

    def on_method_entry(self, method_key: str, this_oid: int) -> list[int]:
        if self.session is not None:
            fn = self._methods.get(method_key)
            if fn is not None:
                # the generated closure is opaque: meter its prefetches
                # through a counting proxy so the online ledger is
                # comparable with the miners' (which count via _emit)
                store = _CountingStore(self.session.store, self.overhead)
                runtime = self.session.runtime
                self.session.runtime.schedule(lambda: fn(store, runtime, this_oid))
            return []
        tree = self._trees.get(method_key)
        if tree is None:
            return []
        return self._emit(expand_hint_tree(self.store, this_oid, tree))
