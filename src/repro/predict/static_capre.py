"""CAPre's code-analysis predictor behind the unified interface.

This is the paper's own strategy: everything is derived at registration
time (``core.hints`` builds PH_m, ``core.injection`` generates the prefetch
closures), so the runtime pays **no monitoring** — ``on_access`` is a no-op
and the only scheduling point is method entry, exactly the injected
``prefetchingExecutor.submit`` of Listing 5.

Online it preserves the historical ``Session(mode="capre")`` behavior
verbatim: the generated closure runs on the session's single-thread
background executor and fans collection hints out on the parallel pool.
Offline (no session) the same hint trees are expanded over the store
snapshot via ``peek`` so the replay harness gets the predicted oid set
without paying I/O.
"""

from __future__ import annotations

import time

from repro.core import lang
from repro.core.injection import _HintTree, build_hint_tree

from .base import Predictor, table_bytes


def iter_hint_tree(store, root_oid: int, tree: _HintTree, on_truncate=None):
    """Lazily yield ``(oid, hint_node)`` pairs a generated prefetch method
    would load for ``root_oid``, in traversal (= needed-at) order, over the
    current store contents without cost accounting.  Lazy matters online:
    the batch dispatcher streams segments off this iterator, so the head of
    a large subtree is already loading while the tail is still being
    expanded — expanding OO7's full design tree before dispatching anything
    made the application demand-miss every subtree's first objects.

    The static-optimizer annotations apply here exactly like in the
    generated closure: siblings expand in priority order, and a collection
    carrying a ``prefix_bound`` yields only its static prefix
    (``on_truncate(node)`` fires once per clipped expansion)."""
    stack: list[tuple[int, _HintTree]] = [(root_oid, tree)]
    while stack:
        oid, node = stack.pop()
        yield oid, node
        rec = store.peek(oid)
        pushes: list[tuple[int, _HintTree]] = []
        for child in node.ordered_children():
            ref = rec.fields.get(child.fld)
            if ref is None:
                continue
            if child.card == lang.COLLECTION:
                elems = list(ref)
                if (child.prefix_bound is not None
                        and len(elems) > child.prefix_bound):
                    elems = elems[: child.prefix_bound]
                    if on_truncate is not None:
                        on_truncate(child)
                pushes.extend((e, child) for e in elems)
            else:
                pushes.append((ref, child))
        stack.extend(reversed(pushes))


def expand_hint_tree(store, root_oid: int, tree: _HintTree) -> list[int]:
    """The oids a generated prefetch method would load for ``root_oid``
    (the eager spelling of ``iter_hint_tree``)."""
    return [oid for oid, _node in iter_hint_tree(store, root_oid, tree)]


class _CountingStore:
    """Thin store proxy charging every ``prefetch_access`` to a predictor's
    ``Overhead`` ledger — the generated prefetch closures cannot do it
    themselves."""

    def __init__(self, store, overhead, rfo_enabled=True, session_label=""):
        self._store = store
        self._overhead = overhead
        self._rfo_enabled = rfo_enabled
        self._session_label = session_label

    def prefetch_access(self, oid: int, rfo: bool = False):
        self._overhead.predictions += 1
        return self._store.prefetch_access(oid, rfo=rfo and self._rfo_enabled,
                                           session=self._session_label)

    def __getattr__(self, name):
        return getattr(self._store, name)


class StaticCapre(Predictor):
    """Hint-driven prefetching — zero runtime monitoring."""

    def __init__(self, config=None, hint_filter=None):
        super().__init__()
        self.config = config
        self.hint_filter = hint_filter  # optional predicate over Hint
        self._methods: dict[str, object] = {}
        self._trees: dict[str, _HintTree] = {}
        # (hint-node id, oid) pairs the batched dispatcher has already
        # expanded this session: recursive traversals (OO7's t1) re-enter
        # nested methods whose hint subtrees were fully expanded by an
        # ancestor's entry — re-expanding them emitted ~5x redundant
        # predictions that predispatch dedupe then threw away one by one
        self._dispatched: set[tuple[int, int]] = set()

    def attach(self, store, reg) -> None:
        super().attach(store, reg)
        if self.hint_filter is None:
            self._methods = dict(reg.prefetch_methods)
            hints = reg.report.hints
        else:
            from repro.core.injection import generate_prefetch_method

            hints = {
                k: tuple(h for h in hs if self.hint_filter(h))
                for k, hs in reg.report.hints.items()
            }
            self._methods = {}
            for k, hs in hints.items():
                fn = generate_prefetch_method(hs)
                if fn is not None:
                    self._methods[k] = fn
        self._trees = {k: build_hint_tree(hs) for k, hs in hints.items() if hs}
        # the analysis is this strategy's entire training cost
        self.overhead.train_seconds += reg.analysis_time_s
        self.overhead.table_bytes = table_bytes(
            sum(len(hs) for hs in hints.values())
        )

    def on_method_entry(self, method_key: str, this_oid: int) -> list[int]:
        if self.session is not None:
            if self._dispatch_mode() == "batch":
                self._schedule_batched(method_key, this_oid)
                return []
            fn = self._methods.get(method_key)
            if fn is not None:
                # the generated closure is opaque: meter its prefetches
                # through a counting proxy so the online ledger is
                # comparable with the miners' (which count via _emit)
                store = _CountingStore(self.session.store, self.overhead,
                                       getattr(self.session.config, "rfo", True),
                                       getattr(self.session, "label", ""))
                runtime = self.session.runtime
                self.session.runtime.schedule(lambda: fn(store, runtime, this_oid))
            return []
        tree = self._trees.get(method_key)
        if tree is None:
            return []
        oids: list[int] = []
        rfo: set[int] = set()
        priorities: dict[int, float] = {}
        for oid, node in iter_hint_tree(self.store, this_oid, tree,
                                        on_truncate=self._note_truncation):
            oids.append(oid)
            if node.rfo:
                rfo.add(oid)
            if node.priority:
                priorities[oid] = node.priority
        return self._emit(oids, context=method_key,
                          rfo=frozenset(rfo), priorities=priorities)

    def _note_truncation(self, _node) -> None:
        self.overhead.truncated_hints += 1

    #: oids per streamed dispatch segment: large enough that executor
    #: submissions stay well below per-oid dispatch, small enough that a
    #: big subtree's head is loading while its tail is still being expanded
    SEGMENT = 64
    #: collection elements per parallel sub-expansion job — discovery of a
    #: large collection's subtrees spreads over the pool (the generated
    #: closure fans out per *element*; grouping keeps task counts an order
    #: of magnitude lower while matching its expansion parallelism)
    SUBTREE_GROUP = 16

    def _schedule_batched(self, method_key: str, this_oid: int) -> None:
        """Batched online dispatch: pool workers expand the hint tree over
        the store snapshot (pure metadata walk, no I/O — the same traversal
        the generated closure performs, so the oid set is identical) and
        stream need-ordered segments to ``prefetch_batch``: one deduped
        request per Data Service per segment instead of one pool task per
        object.  Two lessons from the wall-clock benches are baked in:
        jobs go to the parallel pool, not the single-thread scheduler
        (expansion for every method entry serialized on one thread falls
        behind a fast application — OO7's ~4k entries turned timely
        prefetches into demand misses), and large collections split into
        grouped sub-expansion jobs so discovery parallelism matches the
        per-oid closure's fan-out."""
        tree = self._trees.get(method_key)
        if tree is None:
            return
        self._submit_expansion([(this_oid, tree)], origin=f"capre:{method_key}")

    def _memo_active(self, store) -> bool:
        """Subtree dedupe is only sound while nothing can leave the cache:
        once a pair is dispatched it stays resident or in flight, so
        skipping its re-walk loses no coverage.  Under a bounded capacity
        (or a shared budget) an evicted prefetch must be re-dispatchable —
        the per-oid path re-issues it and the virtual replay re-schedules
        it — so the memo switches off to keep all three semantics
        aligned."""
        return store.budget is None and all(
            ds.cache_capacity == 0 for ds in store.services
        )

    def _submit_expansion(self, roots, origin: str = "capre") -> None:
        store, runtime = self.session.store, self.session.runtime
        rfo_enabled = getattr(self.session.config, "rfo", True)
        label = getattr(self.session, "label", "")

        dispatched = self._dispatched if self._memo_active(store) else None

        def expand_job() -> None:
            seg: list[int] = []
            seg_rfo: set[int] = set()
            seg_prio: dict[int, float] = {}

            def flush() -> None:
                if seg:
                    self.overhead.predictions += len(seg)
                    store.prefetch_batch(seg, runtime=runtime, origin=origin,
                                         rfo=frozenset(seg_rfo),
                                         priorities=dict(seg_prio) or None,
                                         session=label)
                    seg.clear()
                    seg_rfo.clear()
                    seg_prio.clear()

            stack = list(reversed(roots))
            while stack:
                oid, node = stack.pop()
                # dedupe against already-dispatched work at subtree
                # granularity: this exact (hint node, oid) pair was fully
                # expanded by an earlier entry, so its whole subtree is
                # already requested (the emitted SET is unchanged — only
                # the redundant re-walk is skipped).  Sound because an
                # expansion never truncates past its own static bound:
                # reaching a pair means its (bounded) subtree under that
                # node was pushed in the same pass.
                if dispatched is not None:
                    key = (id(node), oid)
                    if key in dispatched:
                        continue
                    dispatched.add(key)
                seg.append(oid)
                if rfo_enabled and node.rfo:
                    seg_rfo.add(oid)
                if node.priority:
                    seg_prio[oid] = node.priority
                if len(seg) >= self.SEGMENT:
                    flush()
                    time.sleep(0)  # yield the GIL between segments
                rec = store.peek(oid)
                pushes = []
                for child in node.ordered_children():
                    ref = rec.fields.get(child.fld)
                    if ref is None:
                        continue
                    if child.card == lang.COLLECTION:
                        elems = list(ref)
                        if (child.prefix_bound is not None
                                and len(elems) > child.prefix_bound):
                            # partial-traversal truncation: the loop behind
                            # this hint provably exits early — expand only
                            # the static prefix
                            elems = elems[: child.prefix_bound]
                            self.overhead.truncated_hints += 1
                        if len(elems) > self.SUBTREE_GROUP:
                            for i in range(0, len(elems), self.SUBTREE_GROUP):
                                self._submit_expansion(
                                    [(e, child) for e in elems[i:i + self.SUBTREE_GROUP]],
                                    origin=origin,
                                )
                            continue
                        pushes.extend((e, child) for e in elems)
                    else:
                        pushes.append((ref, child))
                stack.extend(reversed(pushes))
            flush()

        runtime.submit(expand_job)
