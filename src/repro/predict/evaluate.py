"""Offline trace-replay evaluation harness (DESIGN.md section 3.3).

Turns ``ObjectStore.trace`` from a debugging aid into the substrate for a
head-to-head comparison of every registered predictor:

  1. **record** — run a benchmark workload with prefetching off, capturing
     the interleaved schema-v2 event stream (``pos.trace``): method entries
     (the injected scheduling points), application-path reads *and* writes;
     two cold-cache runs are recorded so trace miners can train on the
     first and be scored on the second (the warm-up run a monitoring
     approach needs anyway).  The apps record concurrently on a thread
     pool — each gets its own store.
  2. **replay** — feed the eval run's events to a fresh instance of each
     predictor under a **virtual clock** driven by the pure-arithmetic side
     of ``pos.latency``: every predicted oid is scheduled on its Data
     Service's ``VirtualDisk`` (``parallel_per_ds`` slots) and gets a
     deterministic *ready-at* time; every access gets a *needed-at* time
     (remote hops + think time advance the application clock).  A bounded
     per-service LRU cache (``cache_capacity``) charges eager predictors
     for thrash evictions.
  3. **score** — precision/recall via the same ``prefetch_accuracy``
     definition the live store uses, **coverage** (order-aware: the oid was
     predicted before the access, latency ignored), and the timeliness
     metrics the paper's argument actually rests on:

     * ``timely_coverage`` — fraction of demand events (reads and writes)
       whose oid was predicted AND resident (ready-at <= needed-at);
     * ``partial_hide``    — fraction whose predicted load was still in
       flight at need (the app stalls for the remainder only);
     * ``stall_seconds``   — simulated disk wait on the app critical path,
       alongside the no-prefetch baseline and the percentage saved.

     Writes are charged end-to-end: an uncached write is write-allocated
     (a demand load on the virtual clock), a resident write dirties its
     cache line, and evicting a dirty line schedules ``write_back``
     occupancy on the same ``VirtualDisk`` slots loads use — so mutating
     workloads (``bank_write`` = the paper's ``setAllTransCustomers``)
     are scored for timeliness too.

Replay is fully deterministic (no real sleeping, no real threads in the
scoring loop), so the CSV artifacts written under ``artifacts/predict/``
are regression-checkable across PRs (``benchmarks/compare_predict.py``).
``benchmarks/bench_predictors.py`` is the wall-clock companion.

Run: ``PYTHONPATH=src python -m repro.predict.evaluate
[--fast] [--apps a,b] [--cache-capacity 0,64,256]
[--cache-policy lru,prefetch-aware] [--shared-budget] [--out artifacts/predict]``
"""

from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.pos.client import POSClient, Session, SessionConfig
from repro.pos.eviction import DEFAULT_POLICY, SharedBudget, make_policy
from repro.pos.latency import REPLAY, LatencyModel, VirtualDisk
from repro.pos.store import prefetch_accuracy
from repro.pos.trace import (
    ACCESS,
    METHOD_ENTRY,
    TRACE_SCHEMA_VERSION,
    WRITE,
    TraceEvent,
    as_events,
    trace_oids,
)

from . import available, make_pos_predictor
from .base import Predictor


# ---------------------------------------------------------------------------
# recording
# ---------------------------------------------------------------------------


@dataclass
class RecordedTrace:
    """One cold-cache run of a workload: the interleaved schema-v2 event
    stream (``pos.trace.TraceEvent``: access / write / method_entry) plus
    the plain demand-oid sequence for bare-oid consumers (miners' ``warm``,
    accuracy sets)."""

    app_name: str
    workload: str
    events: list[TraceEvent]
    accesses: list[int]  # demand-path oids (reads + writes), in order
    schema_version: int = TRACE_SCHEMA_VERSION

    def __len__(self) -> int:
        return len(self.events)


@dataclass
class Workload:
    """A benchmark app + a traversal to trace, in the same shape the
    benchmark driver uses (``run_once(session, root)``).  ``key`` names the
    catalog entry (distinct traversals of one app — e.g. ``bank`` vs
    ``bank_write`` — share ``name``, the registered application)."""

    name: str
    build_app: Callable
    populate: Callable[[object], int]
    run_once: Callable[[Session, int], None]
    workload: str = "run"
    key: str = ""

    def __post_init__(self) -> None:
        if not self.key:
            self.key = self.name


def _catalog() -> dict[str, Workload]:
    """The five paper benchmark apps with trace-friendly (small) sizes."""
    from repro.apps.bank import build_bank_app, populate_bank_store
    from repro.apps.kmeans import build_kmeans_app, initial_centroids, populate_kmeans
    from repro.apps.oo7 import build_oo7_app, populate_oo7
    from repro.apps.pga import build_pga_app, populate_pga
    from repro.apps.wordcount import build_wordcount_app, populate_wordcount

    cents = [list(c) for c in initial_centroids(k=3, dims=6)]
    return {
        "bank": Workload(
            "bank",
            build_bank_app,
            lambda store: populate_bank_store(store, n_transactions=60),
            lambda s, root: s.execute(root, "auditAll"),
            workload="auditAll",
        ),
        # the mutating traversal (paper Listing 1): getAccount navigation +
        # conditional account.cust updates — the write path under test
        "bank_write": Workload(
            "bank",
            build_bank_app,
            lambda store: populate_bank_store(store, n_transactions=60),
            lambda s, root: s.execute(root, "setAllTransCustomers"),
            workload="setAllTransCustomers",
            key="bank_write",
        ),
        "wordcount": Workload(
            "wordcount",
            build_wordcount_app,
            lambda store: populate_wordcount(store, chunks_per_text=8, words_per_chunk=6),
            lambda s, root: s.execute(root, "run"),
        ),
        "kmeans": Workload(
            "kmeans",
            build_kmeans_app,
            lambda store: populate_kmeans(store, n_vectors=240, n_collections=3, dims=6),
            lambda s, root: s.execute(root, "run", cents),
        ),
        "oo7": Workload(
            "oo7",
            build_oo7_app,
            lambda store: populate_oo7(store, size="small"),
            lambda s, root: s.execute(root, "t1"),
            workload="t1",
        ),
        "pga": Workload(
            "pga",
            build_pga_app,
            lambda store: _pga_populate(store, populate_pga),
            lambda s, root: s.execute(root, "dfs"),
            workload="dfs",
        ),
    }


def _pga_populate(store, populate_pga) -> int:
    g, _src = populate_pga(store, n_vertices=120, out_degree=3)
    return g


# -- trace memoization -------------------------------------------------------
#
# Recording a workload means interpreting its full traversal — by far the
# slowest part of an evaluate run, and it was re-executed on every
# invocation (and every test session) even though recording is fully
# deterministic.  Traces are now memoized to disk: the cache entry stores
# the recorded event streams AND the post-recording store contents (field
# values matter — mutating workloads leave the store in the warm state the
# replay's hint expansion reads), guarded by a fingerprint of the freshly
# populated store so any change to an app or its populate sizes invalidates
# the entry.  ``--no-trace-cache`` (or CAPRE_TRACE_CACHE=0) bypasses it.

TRACE_CACHE_VERSION = 2  # v2: blob carries the put log (placement rebuilds)
DEFAULT_TRACE_CACHE_DIR = os.path.join("artifacts", "predict", "traces")


def _resolve_trace_cache(trace_cache: Optional[str]) -> Optional[str]:
    """``None``/empty disables caching; the sentinel ``"default"`` resolves
    the ``CAPRE_TRACE_CACHE`` env override (``0``/empty disables, any other
    value is the cache directory) and falls back to the artifacts dir."""
    if trace_cache != "default":
        return trace_cache or None
    env = os.environ.get("CAPRE_TRACE_CACHE")
    if env is not None:
        return None if env in ("", "0") else env
    return DEFAULT_TRACE_CACHE_DIR


def _trace_cache_path(cache_dir: str, wl: Workload, runs: int, n_services: int) -> str:
    name = (f"{wl.key}_r{runs}_ds{n_services}"
            f"_v{TRACE_SCHEMA_VERSION}.{TRACE_CACHE_VERSION}.json")
    return os.path.join(cache_dir, name)


def _store_fingerprint(store, root: int, reg=None) -> dict:
    """Identity of the freshly populated store — shape counts plus a
    content hash of every object's class and field values, and (when the
    registration is available) of the analysis hints, whose per-method
    navigation structure changes whenever a traversal method's shape
    does.  Any mismatch invalidates the cache entry and re-records.
    Residual blind spot: an edit confined to ``Compute`` bodies that
    flips control flow without touching schema, hints, or populate
    output — use ``--no-trace-cache`` when iterating on those."""
    import hashlib

    h = hashlib.sha1()
    n_objects = 0
    for ds in store.services:
        for oid in sorted(ds.disk):
            rec = ds.disk[oid]
            n_objects += 1
            h.update(repr((ds.ds_id, oid, rec.cls, sorted(rec.fields.items()))).encode())
    if reg is not None:
        h.update(repr(sorted(
            (key, tuple(hint.steps for hint in hints))
            for key, hints in reg.report.hints.items()
        )).encode())
    return {
        "root": root,
        "n_objects": n_objects,
        "n_services": len(store.services),
        "content_sha1": h.hexdigest(),
    }


def _snapshot_store(store) -> list:
    """JSON-serializable dump of every Data Service's disk (oid, class,
    fields) — field values included, so the warm post-recording state of a
    mutating workload round-trips."""
    return [
        [[rec.oid, rec.cls, rec.fields] for _oid, rec in sorted(ds.disk.items())]
        for ds in store.services
    ]


def _apply_store_snapshot(store, snapshot: list, put_log: list) -> None:
    import itertools

    from repro.pos.store import PersistentObject

    store._placement.clear()
    max_oid = 0
    for ds, objs in zip(store.services, snapshot):
        ds.disk.clear()
        for oid, cls, fields in objs:
            ds.disk[oid] = PersistentObject(oid=oid, cls=cls, fields=fields)
            store._placement[oid] = (ds.ds_id,)
            max_oid = max(max_oid, oid)
    # the creation log (oid, cls, group, pin) rides along so a cached store
    # can still rebuild_placement() under another policy/replication
    store._put_log = [
        (oid, cls, group, pin) for oid, cls, group, pin in put_log
    ]
    store._oid_counter = itertools.count(max_oid + 1)


def _load_cached_traces(path: str, wl: Workload, fingerprint: dict) -> Optional[tuple]:
    import json

    try:
        with open(path) as f:
            blob = json.load(f)
    except (OSError, ValueError):
        return None
    if blob.get("fingerprint") != fingerprint:
        return None  # app/populate changed since this entry was written
    traces = [
        RecordedTrace(
            app_name=wl.name,
            workload=wl.workload,
            events=as_events([tuple(ev) for ev in run]),
            accesses=trace_oids([tuple(ev) for ev in run]),
        )
        for run in blob["traces"]
    ]
    return blob["store"], blob.get("put_log", []), traces


def _save_cached_traces(path: str, fingerprint: dict, store,
                        traces: list[RecordedTrace]) -> None:
    import json

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = {
        "fingerprint": fingerprint,
        "store": _snapshot_store(store),
        "put_log": [list(entry) for entry in store._put_log],
        "traces": [[ev.to_tuple() for ev in t.events] for t in traces],
    }
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(blob, f)
    os.replace(tmp, path)  # atomic: concurrent recorders cannot torn-write


def record_workload(
    wl: Workload, runs: int = 2, n_services: int = 4, cache_dir: Optional[str] = None
) -> tuple[POSClient, int, list[RecordedTrace]]:
    """Populate a zero-latency store and record ``runs`` cold-cache traces
    of the workload with prefetching off.  ``ObjectStore.trace`` captures
    the full schema-v2 event stream (method entries via the Session hook,
    reads via ``app_access``, writes via ``app_write``).  Returns the live
    client (replay needs the object graph and the registration analysis)
    plus the traces.  For mutating workloads the train run's updates are
    visible to the eval run — exactly the warm-store regime a monitoring
    predictor trains in.  With ``cache_dir`` the recorded traces (and the
    post-recording store state) are memoized to disk, keyed by workload,
    run count, service count and trace schema version; on a hit the
    traversals are not re-executed."""
    client = POSClient(n_services=n_services)
    reg = client.register(wl.build_app())
    root = wl.populate(client.store)
    path = fingerprint = None
    if cache_dir:
        path = _trace_cache_path(cache_dir, wl, runs, n_services)
        fingerprint = _store_fingerprint(client.store, root, reg)
        if os.path.exists(path):
            cached = _load_cached_traces(path, wl, fingerprint)
            if cached is not None:
                snapshot, put_log, traces = cached
                _apply_store_snapshot(client.store, snapshot, put_log)
                return client, root, traces
    traces = []
    for _ in range(runs):
        client.store.reset_runtime_state()
        client.store.trace = []
        session = Session(client.store, client.logic_module.registered[wl.name])
        try:
            wl.run_once(session, root)
        finally:
            session.close()
        events = list(client.store.trace)
        traces.append(
            RecordedTrace(
                app_name=wl.name,
                workload=wl.workload,
                events=events,
                accesses=trace_oids(events),
            )
        )
        client.store.trace = None
    if path is not None:
        _save_cached_traces(path, fingerprint, client.store, traces)
    return client, root, traces


def record_catalog(
    workloads: Sequence[Workload], runs: int = 2, max_workers: Optional[int] = None,
    cache_dir: Optional[str] = None,
) -> dict[str, tuple[POSClient, int, list[RecordedTrace]]]:
    """Record every workload concurrently, each on its own store, so the
    traces stay byte-identical to serial recording.  On the default
    zero-latency store the interpreter is CPU-bound and the GIL caps the
    overlap; the pool pays off when recording is given a sleeping latency
    model (and costs nothing but threads otherwise).  ``cache_dir`` is
    passed through to ``record_workload`` (disk-memoized traces).  Returns
    ``{workload_key: (client, root, traces)}`` in the order requested."""
    if max_workers is None:
        max_workers = max(1, len(workloads))
    with ThreadPoolExecutor(max_workers=max_workers) as pool:
        futures = {
            wl.key: pool.submit(record_workload, wl, runs, 4, cache_dir)
            for wl in workloads
        }
        return {key: fut.result() for key, fut in futures.items()}


# ---------------------------------------------------------------------------
# virtual-clock replay
# ---------------------------------------------------------------------------


@dataclass
class _CacheEntry:
    source: str  # "pf" | "demand"
    used: bool = False
    dirty: bool = False


class VirtualReplay:
    """The timeliness engine: one ``VirtualDisk`` + bounded LRU per Data
    Service, an application clock advanced by remote hops / stalls / think
    time, and prefetch loads that become resident at their *done* time.

    Semantics mirror the live store: a prefetch loads the object where it
    is stored (no redirection charged); a demand miss queues on the same
    disk slots the prefetches occupy, so over-eager predictors congest the
    application's own loads; concurrent interest in one oid coalesces onto
    the in-flight load.  Writes write-allocate (an uncached write is a
    demand load), dirty their cache line, and evicting a dirty line
    schedules ``write_back`` occupancy on the same disk slots — off the
    app's critical path, but delaying loads queued behind it.

    Eviction order is delegated to the same ``pos.eviction`` policies the
    live ``DataService`` runs, so simulated and measured thrash come from
    one code path.  ``shared_budget=True`` makes ``cache_capacity`` one
    global line budget drawn on by every service (one policy instance spans
    them all and victims are stolen wherever they live), mirroring the
    store's ``SharedBudget`` mode."""

    def __init__(self, store, latency: LatencyModel = REPLAY, cache_capacity: int = 0,
                 policy: str = DEFAULT_POLICY, shared_budget: bool = False,
                 dispatch: str = "per-oid", tracer=None, scenario=None,
                 rfo_enabled: bool = True, executor_workers: int = 8,
                 write_quorum: int = 1):
        from repro.obs import Histogram, Meter

        n = len(store.services)
        self.store = store
        self.latency = latency
        self.cache_capacity = cache_capacity
        self.policy_name = policy
        self.shared_budget = shared_budget and bool(cache_capacity)
        # dispatch granularity mirrored from the live runtime: "per-oid"
        # issues one executor submission per predicted oid (the i-th load
        # starts ~i*dispatch_overhead late — submissions serialize on the
        # dispatching thread); "batch" groups a prediction by Data Service,
        # dedupes against cache + in-flight before submission, and pays one
        # dispatch_overhead per service batch
        self.dispatch = dispatch
        if self.shared_budget:
            # the store's own SharedBudget (owners are Data-Service indices
            # here; its lock is unused — replay is single-threaded)
            self.budget: Optional[SharedBudget] = SharedBudget(cache_capacity, policy=policy)
            self.policies = [self.budget.policy] * n
        else:
            self.budget = None
            self.policies = [make_policy(policy, capacity=cache_capacity) for _ in range(n)]
        # failure regime (pos.latency.FailureScenario): per-service disk
        # scales model stragglers directly on each VirtualDisk; a crash is
        # applied lazily once the virtual clock passes crash_at (so the
        # in-flight prefetch state at that instant is what gets lost) —
        # the virtual-clock mirror of crash_service + failover routing
        self.scenario = scenario
        scales = scenario.straggler_scales() if scenario is not None else {}
        self.dead: set[int] = set()
        # services across the network cut (partitioned regimes): routed
        # around like the dead, but their state survives — at heal they
        # readmit warm and resync the writes they missed
        self.cut: set[int] = set()
        self.failovers = 0  # in-flight prefetch loads re-dispatched off the corpse
        self.crash_lost = 0  # resident lines lost with the crashed cache
        # recovery counters (mirror StoreMetrics)
        self.readmissions = 0
        self.resync_lines = 0
        self.hedged_reads = 0
        self.hedge_wins = 0
        self.quorum_writes = 0
        self.quorum_acks = 0
        self.quorum_retries = 0
        self.quorum_failures = 0
        # replicated writes wait for W-of-R acks (1 = async/sloppy legacy)
        self.write_quorum = max(1, write_quorum)
        # anti-entropy write log: replica -> oids missed while dead/cut
        self._missed_writes: dict[int, set[int]] = {}
        # per-tenant failover attribution for the loadsim driver
        self.failovers_by_tenant: dict[str, int] = {}
        # fault timeline: the scenario's one-shot events, applied lazily in
        # time order the moment the virtual clock passes each (so in-flight
        # state at that instant is what each event catches)
        self._fault_events: list[tuple[float, str]] = []
        if scenario is not None:
            inf = float("inf")
            if scenario.crash_service is not None and scenario.crash_at < inf:
                self._fault_events.append((scenario.crash_at, "crash"))
                if scenario.revive_at < inf:
                    self._fault_events.append((scenario.revive_at, "revive"))
            if scenario.partition and scenario.partition_at < inf:
                self._fault_events.append((scenario.partition_at, "partition"))
                if scenario.heal_at < inf:
                    self._fault_events.append((scenario.heal_at, "heal"))
            self._fault_events.sort()
        self.disks = [VirtualDisk(latency, scale=scales.get(i, 1.0))
                      for i in range(n)]
        self.caches: list[dict[int, _CacheEntry]] = [{} for _ in range(n)]
        self.inflight: list[dict[int, tuple[float, float]]] = [{} for _ in range(n)]
        self.t = 0.0
        self.cur_ds: Optional[int] = None
        # counters (n_access counts every demand event, reads and writes)
        self.n_access = 0
        self.timely = 0
        self.partial = 0
        self.remote_hops = 0
        self.stall_seconds = 0.0
        self.hidden_seconds = 0.0
        self.demand_loads = 0
        self.prefetch_loads = 0
        self.prefetch_requests = 0
        self.evictions = 0
        self.evicted_before_use = 0
        self.thrash_misses = 0
        self.writes = 0
        self.write_hits = 0  # writes that found the line resident
        self.dirty_evictions = 0
        self.flushed_writes = 0
        self.batch_dispatches = 0  # executor submissions the predictions cost
        self.dedup_suppressed = 0  # oids suppressed before submission (batch mode)
        # -- static-optimizer signals (core.opt) ----------------------------
        # rfo_enabled=False ignores read-for-ownership marks (the A/B
        # control): predictions then never dirty-allocate, and every write
        # to a resident-but-clean line pays the ownership upgrade below
        self.rfo_enabled = rfo_enabled
        self.rfo_prefetches = 0  # prefetch loads landed dirty (RFO)
        self.ownership_upgrades = 0  # writes to resident-but-clean lines
        self._rfo_pending: list[set[int]] = [set() for _ in range(n)]
        # priority stream accounting (mean static priority of the emitted
        # predictions — the bench/compare artifact column)
        self._prio_sum = 0.0
        self._prio_n = 0
        # bounded prefetch-executor pool mirroring the live PrefetchRuntime
        # (parallel_workers=8): a dispatch task occupies a worker slot from
        # its issue until its loads are ready — when predictions outpace the
        # pool, later dispatches queue behind busy workers instead of
        # issuing instantly (the saturation the wall-clock benches hit)
        self._exec_slots = [0.0] * max(1, executor_workers)
        self.exec_delayed = 0  # dispatches that waited for a free worker
        self._evicted_ever: set[int] = set()
        # observability (repro.obs): the virtual clock affords an *exact*
        # per-demand-event stall distribution (every event records 0.0 on a
        # residency hit, the remainder on a partial, the full queue+service
        # wait on a miss) and, optionally, the same lifecycle spans the live
        # store traces — virtual timestamps land in the same span fields, so
        # wall and virtual timelines export through one code path.  The
        # instrumentation's own wall cost accrues on ``obs_meter``.
        self.obs_meter = Meter()
        self.stall_hist = Histogram("stall_s", exact=True, meter=self.obs_meter)
        self.tracer = tracer
        if tracer is not None and tracer.meter is None:
            tracer.meter = self.obs_meter
        # -- multi-tenant attribution (predict.loadsim) ----------------------
        # the loadsim driver time-multiplexes several tenants over one
        # engine (shared disks/caches/executor = the interference model) by
        # setting ``active_tenant`` around each event; prefetched lines
        # remember which tenant scheduled them so an eviction-before-use
        # can be charged to the tenant whose working set was destroyed
        self.active_tenant = ""
        self._pf_owner: dict[int, str] = {}
        self.evicted_by_tenant: dict[str, int] = {}

    # -- cache mechanics ----------------------------------------------------

    def _materialize(self, ds_i: int, t: float) -> None:
        """Promote in-flight loads that completed by ``t`` to resident, in
        completion order (so LRU age matches the virtual timeline).  An
        RFO-marked load lands dirty: the line is owned for writing the
        moment it becomes resident."""
        landed = sorted(
            (done, oid) for oid, (_start, done) in self.inflight[ds_i].items() if done <= t
        )
        for _done, oid in landed:
            del self.inflight[ds_i][oid]
            self._insert(ds_i, oid, "pf")
            self._land_rfo(ds_i, oid)

    def _land_rfo(self, ds_i: int, oid: int) -> None:
        """Dirty-allocate a just-landed prefetch if it was issued RFO."""
        if oid not in self._rfo_pending[ds_i]:
            return
        self._rfo_pending[ds_i].discard(oid)
        entry = self.caches[ds_i].get(oid)
        if entry is not None:
            entry.dirty = True
        self.rfo_prefetches += 1

    def _exec_issue(self, req_t: float) -> tuple[int, float]:
        """Claim the earliest-free prefetch-executor worker for a dispatch
        requested at ``req_t``: returns ``(slot, issue_t)`` where the issue
        waits out the pool when every worker is busy."""
        i = min(range(len(self._exec_slots)), key=self._exec_slots.__getitem__)
        issue = max(req_t, self._exec_slots[i])
        if issue > req_t:
            self.exec_delayed += 1
        return i, issue

    @property
    def protected_evictions(self) -> int:
        policies = {id(p): p for p in self.policies}
        return sum(p.protected_evictions for p in policies.values())

    def _insert(self, ds_i: int, oid: int, source: str, used: bool = False) -> None:
        cache = self.caches[ds_i]
        if oid in cache:
            self.policies[ds_i].note_access(oid, prefetch=(source == "pf"))
        elif self.budget is not None:
            cache[oid] = _CacheEntry(source, used)
            self.budget.note_insert(oid, ds_i, prefetch=(source == "pf"), used=used)
        else:
            cache[oid] = _CacheEntry(source, used)
            self.policies[ds_i].note_insert(oid, prefetch=(source == "pf"), used=used)
        self._evict_overflow(ds_i)

    def _evict_overflow(self, ds_i: int) -> None:
        if not self.cache_capacity:
            return
        if self.budget is not None:
            while self.budget.overflowed():
                holders, victim_oid = self.budget.pick_victim()
                for vds_i in sorted(holders):  # deterministic copy order
                    self._evict(vds_i, victim_oid)
        else:
            while len(self.caches[ds_i]) > self.cache_capacity:
                self._evict(ds_i, self.policies[ds_i].pick_victim())

    def _evict(self, ds_i: int, victim_oid: int) -> None:
        victim = self.caches[ds_i].pop(victim_oid)
        self.evictions += 1
        if self.tracer is not None:
            self.tracer.evicted(victim_oid, t=self.t)
        self._evicted_ever.add(victim_oid)
        if victim.source == "pf" and not victim.used:
            self.evicted_before_use += 1
            owner = self._pf_owner.pop(victim_oid, "")
            if owner:
                # interference: the tenant who prefetched this line lost it
                # before ever using it (evicted by whoever overflowed the
                # shared budget)
                self.evicted_by_tenant[owner] = \
                    self.evicted_by_tenant.get(owner, 0) + 1
        if victim.dirty:
            # the deferred cost of the write path: the flush occupies a
            # disk slot now, delaying whatever loads queue behind it
            self.dirty_evictions += 1
            self.flushed_writes += 1
            self.disks[ds_i].schedule_write_back(self.t)

    # -- replica routing & failure injection ---------------------------------

    def _route(self, oid: int) -> int:
        """Virtual mirror of ``ObjectStore._route_demand``: primary when
        replication is 1 (byte-identical legacy behavior), else the alive
        replica that already holds / is loading the line, falling back to
        the least-queued disk (earliest-free slot; ties in replica order).
        Stragglers deprioritize themselves here — their slots free later."""
        from repro.pos.store import NoReplicaAvailable

        reps = self.store.replicas_of(oid)
        if len(reps) == 1:
            if reps[0] in self.dead or reps[0] in self.cut:
                raise NoReplicaAvailable(oid, reps)
            return reps[0]
        alive = [i for i in reps if i not in self.dead and i not in self.cut]
        if not alive:
            raise NoReplicaAvailable(oid, reps)
        for i in alive:
            if oid in self.caches[i] or oid in self.inflight[i]:
                return i
        return min(alive, key=lambda i: (min(self.disks[i]._slots),
                                         reps.index(i)))

    def _route_prefetch(self, oid: int) -> Optional[int]:
        """Prefetch routing: like ``_route`` but an unreachable object is
        skipped (None) instead of raising — demand surfaces real losses."""
        reps = self.store.replicas_of(oid)
        alive = [i for i in reps if i not in self.dead and i not in self.cut]
        if not alive:
            return None
        if len(alive) == 1:
            return alive[0]
        for i in alive:
            if oid in self.caches[i] or oid in self.inflight[i]:
                return i
        return min(alive, key=lambda i: (min(self.disks[i]._slots),
                                         reps.index(i)))

    def _advance_faults(self) -> None:
        """Apply the scenario's one-shot fault events (crash, partition,
        heal, revive) that the virtual clock has reached, in time order.
        Each event may advance the clock (detection delays), so the loop
        re-checks until no pending event is due."""
        while self._fault_events and self.t >= self._fault_events[0][0]:
            at, kind = self._fault_events.pop(0)
            if kind == "crash":
                self._apply_crash(at)
            elif kind == "partition":
                self._apply_partition(at)
            elif kind == "heal":
                self._apply_heal(at)
            elif kind == "revive":
                self._apply_revive(at)

    def _apply_crash(self, at: float) -> None:
        """The scenario's crash: the service's resident cache dies, its
        in-flight prefetch loads are re-dispatched onto a surviving replica
        ``failover_delay`` after the crash (mirroring
        ``_failover_redispatch`` on the live store), and the application
        clock eats the detection delay once."""
        sc = self.scenario
        i = sc.crash_service
        self.dead.add(i)
        tr = self.tracer
        if tr is not None:
            tr.instant("service-crash", service=i, t=at)
        cache = self.caches[i]
        for oid in list(cache):
            entry = cache.pop(oid)
            if self.budget is not None:
                self.budget.note_remove(oid, i)
            else:
                self.policies[i].note_remove(oid)
            self.crash_lost += 1
            self._evicted_ever.add(oid)
            if entry.source == "pf" and not entry.used:
                self.evicted_before_use += 1
                owner = self._pf_owner.pop(oid, "")
                if owner:
                    self.evicted_by_tenant[owner] = \
                        self.evicted_by_tenant.get(owner, 0) + 1
            if tr is not None:
                tr.evicted(oid, t=at)
        pend, self.inflight[i] = dict(self.inflight[i]), {}
        if tr is not None and pend:
            tr.dropped(list(pend), "service-crash", t=at)
        re_t = at + sc.failover_delay
        self._redispatch(pend, re_t)
        if tr is not None:
            tr.instant("prefetch-failover", service=i, t=re_t,
                       oids=len(pend))
        self.t += sc.failover_delay  # the app notices the failover once

    def _redispatch(self, pend, re_t: float) -> None:
        """Re-dispatch in-flight prefetch loads lost to a crash/partition
        onto reachable replicas at ``re_t`` (one failover per load, charged
        to the tenant whose prefetch it was)."""
        tr = self.tracer
        for oid in pend:
            alt = self._route_prefetch(oid)
            if alt is None:
                continue  # replication 1: the load is simply lost
            start, done = self.disks[alt].schedule(re_t)
            self.inflight[alt][oid] = (start, done)
            self.failovers += 1
            owner = self._pf_owner.get(oid, "")
            if owner:
                self.failovers_by_tenant[owner] = \
                    self.failovers_by_tenant.get(owner, 0) + 1
            self.prefetch_loads += 1
            if tr is not None:
                tr.predicted([oid], "failover", t=re_t)
                tr.dispatched([oid], alt, tr.new_batch(), t=re_t)
                tr.claimed([oid], alt, t=re_t)
                tr.loaded([oid], alt, self.disks[alt].last_slot,
                          re_t, start, done)

    def _apply_partition(self, at: float) -> None:
        """The scenario's network cut: services outside group 0 become
        unreachable.  Their caches and disks survive (unlike a crash); the
        client-side runtime re-dispatches the loads it was waiting on to
        reachable replicas, and the app notices the cut once (one
        detection failover, mirroring the first tripped demand access)."""
        sc = self.scenario
        cut = sc.cut_services()
        self.cut |= cut
        tr = self.tracer
        if tr is not None:
            tr.instant("partition", t=at, cut=sorted(cut))
        re_t = at + sc.failover_delay
        for i in sorted(cut):
            # leave the cut-side in-flight loads in place: they complete
            # server-side and are warm when the partition heals — but the
            # client cannot see them, so they also re-dispatch client-side
            self._redispatch(dict(self.inflight[i]), re_t)
        # detection: the first access that trips over the cut
        self.failovers += 1
        if self.active_tenant:
            self.failovers_by_tenant[self.active_tenant] = \
                self.failovers_by_tenant.get(self.active_tenant, 0) + 1
        self.t += sc.failover_delay

    def _apply_heal(self, at: float) -> None:
        """Heal the cut: every cut service readmits WARM (its cache and the
        loads that completed server-side survive) and anti-entropy resyncs
        the dirty lines whose writes it missed (write-backs on its own disk
        slots, off the app's critical path)."""
        healed, self.cut = set(self.cut), set()
        for i in sorted(healed):
            self._materialize(i, at)
            self.readmissions += 1
            self._resync(i, at)
        tr = self.tracer
        if tr is not None:
            tr.instant("partition-heal", t=at, healed=sorted(healed))

    def _apply_revive(self, at: float) -> None:
        """The crashed service returns: COLD cache (the crash destroyed
        it), rejoins routing, resyncs missed writes."""
        i = self.scenario.crash_service
        self.dead.discard(i)
        self.readmissions += 1
        self._resync(i, at)
        tr = self.tracer
        if tr is not None:
            tr.instant("service-readmit", service=i, t=at)

    def _resync(self, ds_i: int, at: float) -> None:
        """Anti-entropy replay of the write log a returning replica missed:
        one write-back per missed line on the replica's own disk."""
        missed = self._missed_writes.pop(ds_i, set())
        for _oid in sorted(missed):
            self.disks[ds_i].schedule_write_back(at)
            self.resync_lines += 1
            self.flushed_writes += 1

    def _maybe_hedge(self, oid: int, ds_i: int,
                     needed_at: float) -> tuple[int, float, float]:
        """Demand-load dispatch with the hedge race applied on top."""
        start, done = self.disks[ds_i].schedule(needed_at)
        win, w_done = self._hedge_race(oid, ds_i, needed_at, done)
        return win, start, w_done

    def _hedge_race(self, oid: int, ds_i: int, needed_at: float,
                    done: float) -> tuple[int, float]:
        """Read hedging: if hedging is armed and the primary copy (a
        demand load just scheduled, or a prefetch already in flight,
        completing at ``done``) would not land within the hedge delay,
        race a fresh demand read on a second replica issued ``delay``
        after the need and take the first response.  Both disks stay
        charged (the loser's work is real); the losing replica's line is
        *not* retained (a shared budget keys lines by oid — one oid under
        two owners would collide).  Returns the winning ``(service,
        done)`` pair."""
        sc = self.scenario
        if sc is None or not sc.hedge:
            return ds_i, done
        delay = sc.hedge_delay or 3.0 * self.latency.disk_load
        if done - needed_at <= delay:
            return ds_i, done
        reps = self.store.replicas_of(oid)
        alts = [i for i in reps
                if i != ds_i and i not in self.dead and i not in self.cut]
        if not alts:
            return ds_i, done
        alt = min(alts, key=lambda i: (min(self.disks[i]._slots),
                                       reps.index(i)))
        _start, a_done = self.disks[alt].schedule(needed_at + delay)
        self.hedged_reads += 1
        won = a_done < done
        if won:
            self.hedge_wins += 1
        if self.tracer is not None:
            self.tracer.instant("hedged-read", service=alt,
                                t=needed_at + delay, oid=oid, win=won)
        if won:
            return alt, a_done
        return ds_i, done

    def _note_missed_replicas(self, oid: int, ds_i: int) -> None:
        """A dirty write whose replica set includes an unreachable service
        goes into that service's missed-write log; readmission (heal or
        revive) replays it via anti-entropy resync."""
        if not self.dead and not self.cut:
            return
        for r in self.store.replicas_of(oid):
            if r != ds_i and (r in self.dead or r in self.cut):
                self._missed_writes.setdefault(r, set()).add(oid)

    MAX_QUORUM_RETRIES = 4

    def _await_quorum(self, oid: int, ds_i: int) -> None:
        """Synchronous W-of-R write replication on the virtual clock: the
        writer waits for ``write_quorum - 1`` replica acks, one remote hop
        each, serialized on the app clock like the live store's ack waits
        (the wait counts as stall, so ``end_t = t - stall`` stays anchored
        on the quorum-free schedule).  An unreachable quorum retries with
        exponential backoff — ``_advance_faults`` runs between attempts so
        a scheduled heal can unblock the wait — and degrades to sloppy
        replication when retries exhaust (the missed replicas resync at
        readmission; the live store raises ``QuorumUnreachable`` instead)."""
        reps = self.store.replicas_of(oid)
        want = min(self.write_quorum, len(reps))
        if want <= 1:
            return
        backoff = max(self.latency.failover_detect, self.latency.disk_load)
        for attempt in range(self.MAX_QUORUM_RETRIES + 1):
            reachable = [r for r in reps
                         if r not in self.dead and r not in self.cut]
            if len(reachable) >= want:
                wait = (want - 1) * self.latency.remote_hop
                self.t += wait
                self.stall_seconds += wait
                self.quorum_writes += 1
                # W-1 synchronous replica acks per write — the same
                # definition the live store's counter uses
                self.quorum_acks += want - 1
                for r in reachable:
                    if r == ds_i:
                        continue
                    e = self.caches[r].get(oid)
                    if e is not None:
                        e.dirty = True  # replicated write dirties the copy
                return
            if attempt == self.MAX_QUORUM_RETRIES:
                break
            pause = backoff * (2 ** attempt)
            self.t += pause
            self.stall_seconds += pause
            self.quorum_retries += 1
            self._advance_faults()
        self.quorum_failures += 1
        if self.tracer is not None:
            self.tracer.instant("quorum-unreachable", t=self.t, oid=oid,
                                wanted=want)

    # -- the two event kinds -------------------------------------------------

    def predict(self, oids: Sequence[int], origin: str = "",
                rfo: frozenset = frozenset(),
                priorities: Optional[dict] = None) -> None:
        """Predictor emitted ``oids`` at the current virtual time: schedule
        a disk load on each one's own Data Service unless already resident
        or in flight (request coalescing).  Dispatch overhead charges at
        the configured granularity — per oid, or per Data-Service batch —
        by delaying the *issue* time of the loads (the submitting side
        serializes task starts; the application clock itself is not
        advanced, prefetch dispatch runs on background threads), and every
        dispatch additionally queues for one of the bounded executor-pool
        workers.  ``rfo`` oids land dirty (read-for-ownership);
        ``priorities`` orders batched per-service dispatch and feeds the
        mean-priority artifact column."""
        self._advance_faults()
        if not self.rfo_enabled:
            rfo = frozenset()
        if priorities:
            self._prio_sum += sum(priorities.values())
            self._prio_n += len(priorities)
        if self.dispatch == "batch":
            self._predict_batched(oids, origin=origin, rfo=rfo,
                                  priorities=priorities)
            return
        tr = self.tracer
        overhead = self.latency.dispatch_overhead
        for i, oid in enumerate(oids):
            ds_i = self._route_prefetch(oid)
            if ds_i is None:
                continue  # no reachable replica: skip, demand surfaces it
            # promote completions up to the app clock only — a load issued
            # earlier in this very emission is *in flight*, not resident
            self._materialize(ds_i, self.t)
            self.prefetch_requests += 1
            self.batch_dispatches += 1  # per-oid: every oid is a submission
            if tr is not None:
                tr.predicted([oid], origin, t=self.t)
                tr.dispatched([oid], ds_i, tr.new_batch(), t=self.t)
            cache = self.caches[ds_i]
            if oid in cache:
                # policy bump only (a prefetch touch must not count as the
                # application using the line), keep source/used
                self.policies[ds_i].note_access(oid, prefetch=True)
                if tr is not None:
                    tr.suppressed([oid], ds_i, t=self.t)
                continue
            if oid in self.inflight[ds_i]:
                if tr is not None:
                    tr.suppressed([oid], ds_i, t=self.t)
                continue
            slot, issue_t = self._exec_issue(self.t + (i + 1) * overhead)
            start, done = self.disks[ds_i].schedule(issue_t)
            self._exec_slots[slot] = done  # worker busy until the load lands
            self.inflight[ds_i][oid] = (start, done)
            if self.active_tenant:
                self._pf_owner[oid] = self.active_tenant
            if oid in rfo:
                self._rfo_pending[ds_i].add(oid)
            self.prefetch_loads += 1
            if tr is not None:
                tr.claimed([oid], ds_i, t=issue_t)
                tr.loaded([oid], ds_i, self.disks[ds_i].last_slot,
                          issue_t, start, done)

    def _predict_batched(self, oids: Sequence[int], origin: str = "",
                         rfo: frozenset = frozenset(),
                         priorities: Optional[dict] = None) -> None:
        """The batched mirror of ``ObjectStore.prefetch_batch``: group by
        owning Data Service in predicted-need order, dedupe each group
        against residency and in-flight loads before submission, then issue
        the surviving loads as one pipelined batch on the service's disk.
        With ``priorities`` the groups dispatch highest-priority-first
        (the live path orders identically)."""
        groups: dict[int, list[int]] = {}
        for oid in oids:
            ds_i = self._route_prefetch(oid)
            if ds_i is None:
                continue  # no reachable replica: skip, demand surfaces it
            groups.setdefault(ds_i, []).append(oid)
        ordered = list(groups.items())
        if priorities:
            ordered.sort(key=lambda kv: -max(
                (priorities.get(o, 0.0) for o in kv[1]), default=0.0))
        tr = self.tracer
        overhead = self.latency.dispatch_overhead
        submitted = 0
        for ds_i, batch in ordered:
            self._materialize(ds_i, self.t)
            if tr is not None:
                tr.predicted(batch, origin, t=self.t)
                tr.dispatched(batch, ds_i, tr.new_batch(), t=self.t)
            todo: list[int] = []
            claimed: set[int] = set()
            cache = self.caches[ds_i]
            for oid in batch:
                self.prefetch_requests += 1
                if oid in cache:
                    self.policies[ds_i].note_access(oid, prefetch=True)
                    self.dedup_suppressed += 1
                elif oid in self.inflight[ds_i] or oid in claimed:
                    self.dedup_suppressed += 1
                else:
                    claimed.add(oid)
                    todo.append(oid)
            if tr is not None:
                lost = [o for o in batch if o not in claimed]
                if lost:
                    tr.suppressed(lost, ds_i, t=self.t)
            if not todo:
                continue
            submitted += 1
            self.batch_dispatches += 1
            slot, issue_t = self._exec_issue(self.t + submitted * overhead)
            disk = self.disks[ds_i]
            batch_done = issue_t
            for oid in todo:
                start, done = disk.schedule(issue_t)
                batch_done = max(batch_done, done)
                self.inflight[ds_i][oid] = (start, done)
                if self.active_tenant:
                    self._pf_owner[oid] = self.active_tenant
                if oid in rfo:
                    self._rfo_pending[ds_i].add(oid)
                self.prefetch_loads += 1
                if tr is not None:
                    tr.claimed([oid], ds_i, t=issue_t)
                    tr.loaded([oid], ds_i, disk.last_slot, issue_t, start, done)
            # the batch task occupies its executor worker until its last
            # load is ready (claim + slot wait + disk service, like the
            # live _load_lane worker)
            self._exec_slots[slot] = batch_done

    def access(self, oid: int, write: bool = False) -> None:
        """Application touches ``oid`` (read navigation, or field update
        when ``write``): redirect execution if needed, then wait out
        whatever part of the disk load prefetching did not hide.  A write
        to an uncached object write-allocates — the same demand load a read
        pays — and always leaves the line dirty."""
        self._advance_faults()
        ds_i = self._route(oid)
        if self.cur_ds != ds_i:
            self.t += self.latency.remote_hop
            self.cur_ds = ds_i
            self.remote_hops += 1
        self._materialize(ds_i, self.t)
        self.n_access += 1
        if write:
            self.writes += 1
        needed_at = self.t
        tr = self.tracer
        # per-service disk time (straggler scales fold in; exact x*1.0
        # multiplication keeps no-fault accounting byte-identical)
        disk_s = self.disks[ds_i]._disk_load
        cache = self.caches[ds_i]
        entry = cache.get(oid)
        owned = False  # did this very access acquire write ownership?
        if entry is not None:
            # resident: ready-at <= needed-at. Timely iff prefetching (not a
            # prior demand load) put it there.
            self.policies[ds_i].note_access(oid)
            if entry.source == "pf":
                if not entry.used:
                    self.hidden_seconds += disk_s
                self.timely += 1
            entry.used = True
            self._pf_owner.pop(oid, None)  # used: no longer an unused-pf line
            if write:
                self.write_hits += 1
            self.stall_hist.record(0.0)
            if tr is not None:
                tr.demand(oid, ds_i, needed_at, 0.0, False,
                          disk_s, t=needed_at)
        elif oid in self.inflight[ds_i]:
            # predicted, still in flight: the app waits out the remainder
            # (a straggling in-flight load is exactly what hedging cuts —
            # a fresh demand read on another replica can beat it)
            _start, done = self.inflight[ds_i].pop(oid)
            orig = ds_i
            ds_i, done = self._hedge_race(oid, ds_i, needed_at, done)
            self.cur_ds = ds_i
            stall = done - needed_at
            self.stall_seconds += stall
            self.hidden_seconds += max(0.0, disk_s - stall)
            self.t = done
            self.partial += 1
            self._pf_owner.pop(oid, None)
            self._insert(ds_i, oid, "pf", used=True)
            entry = self.caches[ds_i].get(oid)
            if ds_i != orig and oid in self._rfo_pending[orig]:
                # the RFO mark travels with the object, not the replica
                self._rfo_pending[orig].discard(oid)
                self._rfo_pending[ds_i].add(oid)
            self._land_rfo(ds_i, oid)  # an RFO load lands dirty (owned)
            self.stall_hist.record(stall)
            if tr is not None:
                tr.demand(oid, ds_i, needed_at, stall, False,
                          disk_s, t=done)
        else:
            # unpredicted (or evicted): full demand load, queueing behind
            # whatever the prefetcher has piled onto this service's disk
            # (with hedging armed, a slow primary races a second replica)
            ds_i, _start, done = self._maybe_hedge(oid, ds_i, needed_at)
            self.cur_ds = ds_i  # execution follows the replica that answered
            stall = done - needed_at
            self.stall_seconds += stall
            self.t = done
            self.demand_loads += 1
            if oid in self._evicted_ever:
                self.thrash_misses += 1
            self._insert(ds_i, oid, "demand", used=True)
            entry = self.caches[ds_i].get(oid)
            owned = True  # write-allocate acquires ownership with the load
            self.stall_hist.record(stall)
            if tr is not None:
                tr.demand(oid, ds_i, needed_at, stall, True,
                          disk_s, t=done)
        if write and entry is not None:
            if not entry.dirty and not owned:
                # ownership upgrade: writing a resident-but-clean line pays
                # a round trip to acquire write ownership on the app clock —
                # the cost an RFO prefetch (dirty-allocated landing) removes
                self.t += self.latency.remote_hop
                self.stall_seconds += self.latency.remote_hop
                self.ownership_upgrades += 1
            entry.dirty = True
        if write:
            self._note_missed_replicas(oid, ds_i)
            if self.write_quorum > 1:
                self._await_quorum(oid, ds_i)
        self.t += self.latency.think

    def write(self, oid: int) -> None:
        self.access(oid, write=True)


@dataclass
class ReplayResult:
    app: str
    workload: str
    predictor: str
    cache_capacity: int
    policy: str
    shared_budget: bool
    dispatch: str
    precision: Optional[float]
    recall: Optional[float]
    evaluated: bool
    coverage: float
    timely_coverage: float
    partial_hide: float
    stall_seconds: float
    baseline_stall_seconds: float
    stall_saved_pct: float
    true_positives: int
    false_positives: int
    false_negatives: int
    evictions: int
    thrash_misses: int
    prefetch_loads: int
    writes: int
    write_hits: int
    dirty_evictions: int
    flushed_writes: int
    batch_dispatches: int
    dedup_suppressed: int
    # per-operation stall distribution (exact percentiles over every demand
    # event on the virtual clock: 0.0 = fully hidden / resident, up to a
    # full queued demand load) — the tail metrics the multi-tenant
    # north-star reports
    stall_p50_s: float = 0.0
    stall_p99_s: float = 0.0
    stall_p999_s: float = 0.0
    # virtual stalls re-expressed in calibrated wall seconds (the fitted
    # per-app scale from artifacts/predict/calibration.csv; 1.0 = unfitted)
    calib_scale: float = 1.0
    calibrated_stall_s: float = 0.0
    # topology + failure regime the row was replayed under
    placement: str = "round-robin"
    replication: int = 1
    write_quorum: int = 1
    scenario: str = "no-fault"
    failovers: int = 0
    overhead: dict = field(default_factory=dict)

    def row(self) -> dict:
        out = dict(self.__dict__)
        out.update(out.pop("overhead"))
        return out


def replay_baseline(
    trace: RecordedTrace, store, latency: LatencyModel = REPLAY, cache_capacity: int = 0,
    policy: str = DEFAULT_POLICY, shared_budget: bool = False, scenario=None,
    write_quorum: int = 1
) -> VirtualReplay:
    """The no-prefetch reference: every cold (or thrashed-out) demand event
    pays the full disk load (writes included — write-allocate + dirty
    evictions).  Same trace, same clock, same eviction policy, no
    predictions.  A fault ``scenario`` applies to the baseline too — the
    reference for a faulted replay is the same faults without prefetch
    (likewise ``write_quorum``: the reference prices the same consistency)."""
    engine = VirtualReplay(store, latency=latency, cache_capacity=cache_capacity,
                           policy=policy, shared_budget=shared_budget,
                           scenario=scenario, write_quorum=write_quorum)
    for ev in as_events(trace.events):
        if ev.kind == ACCESS:
            engine.access(ev.oid)
        elif ev.kind == WRITE:
            engine.write(ev.oid)
    return engine


def replay(
    trace: RecordedTrace,
    predictor: Predictor,
    store,
    reg,
    latency: LatencyModel = REPLAY,
    cache_capacity: int = 0,
    policy: str = DEFAULT_POLICY,
    shared_budget: bool = False,
    dispatch: str = "per-oid",
    baseline_stall_seconds: Optional[float] = None,
    tracer=None,
    calibration=None,
    scenario=None,
    rfo: bool = True,
    write_quorum: int = 1,
) -> ReplayResult:
    """Drive ``predictor`` through the recorded event stream on the virtual
    clock and score what its prefetches would have hidden.  Pass a
    ``repro.obs.Tracer`` to collect full lifecycle spans (virtual
    timestamps), a ``predict.calibration.Calibration`` to report the stalls
    in calibrated wall seconds too, and a ``pos.latency.FailureScenario``
    to replay under a straggler/crash regime (the store's placement +
    replication are read off ``store`` itself — ``rebuild_placement``
    first to sweep policies)."""
    predictor.attach(store, reg)
    engine = VirtualReplay(store, latency=latency, cache_capacity=cache_capacity,
                           policy=policy, shared_budget=shared_budget, dispatch=dispatch,
                           tracer=tracer, scenario=scenario, rfo_enabled=rfo,
                           write_quorum=write_quorum)
    name = predictor.name
    predicted: set[int] = set()
    accessed: set[int] = set()
    n_access, covered = 0, 0
    for ev in as_events(trace.events):
        if ev.kind == METHOD_ENTRY:
            out = predictor.on_method_entry(ev.method_key, ev.oid)
            predicted.update(out)
            rfo_oids, priorities = predictor.take_emission_meta()
            engine.predict(out, origin=f"{name}:{ev.method_key}",
                           rfo=rfo_oids, priorities=priorities or None)
        else:
            oid = ev.oid
            n_access += 1
            if oid in predicted:
                covered += 1
            accessed.add(oid)
            if ev.kind == WRITE:
                engine.write(oid)
                out = predictor.on_write(oid, store.cls_of(oid))
            else:
                engine.access(oid)
                out = predictor.on_access(oid, store.cls_of(oid))
            predicted.update(out)
            rfo_oids, priorities = predictor.take_emission_meta()
            engine.predict(out, origin=f"{name}:on_access",
                           rfo=rfo_oids, priorities=priorities or None)
    if tracer is not None:
        # lifecycle invariant at end of run: still-active spans (predicted
        # or resident-but-never-demanded) terminate as dropped
        tracer.drop_active("replay-end", t=engine.t)
    if baseline_stall_seconds is None:
        baseline_stall_seconds = replay_baseline(
            trace, store, latency=latency, cache_capacity=cache_capacity,
            policy=policy, shared_budget=shared_budget, scenario=scenario,
            write_quorum=write_quorum,
        ).stall_seconds
    saved = (
        100.0 * (1.0 - engine.stall_seconds / baseline_stall_seconds)
        if baseline_stall_seconds
        else 0.0
    )
    acc = prefetch_accuracy(predicted, accessed)
    overhead = predictor.overhead.snapshot()
    # timeliness costs land on the ledger snapshot (Hybrid derives its
    # ledger from its parts, so mutate the dict, not the property)
    overhead["late_predictions"] = engine.partial
    overhead["evicted_before_use"] = engine.evicted_before_use
    overhead["hidden_seconds"] = engine.hidden_seconds
    overhead["protected_evictions"] = engine.protected_evictions
    overhead["batch_dispatches"] = engine.batch_dispatches
    overhead["dedup_suppressed"] = engine.dedup_suppressed
    # static-optimizer accounting on the virtual clock: RFO prefetch
    # landings, write-to-clean ownership upgrades the app paid anyway,
    # modeled executor-pool waits, and the mean static priority seen
    overhead["rfo_prefetches"] = engine.rfo_prefetches
    overhead["ownership_upgrades"] = engine.ownership_upgrades
    overhead["exec_delayed"] = engine.exec_delayed
    overhead["hint_priority_mean"] = (
        round(engine._prio_sum / engine._prio_n, 4) if engine._prio_n else 0.0
    )
    # what the instruments themselves cost this replay (histogram recording
    # + span bookkeeping), charged to the ledger like any other overhead
    overhead["obs_seconds"] = engine.obs_meter.seconds
    overhead["obs_events"] = engine.obs_meter.events
    # recovery accounting (partition/readmission/quorum/hedging regimes)
    overhead["readmissions"] = engine.readmissions
    overhead["resync_lines"] = engine.resync_lines
    overhead["hedged_reads"] = engine.hedged_reads
    overhead["hedge_wins"] = engine.hedge_wins
    overhead["quorum_writes"] = engine.quorum_writes
    overhead["quorum_acks"] = engine.quorum_acks
    overhead["quorum_retries"] = engine.quorum_retries
    overhead["quorum_failures"] = engine.quorum_failures
    p50, p99, p999 = engine.stall_hist.percentiles((0.5, 0.99, 0.999))
    scale = (calibration.scale_for(_calibration_app_key(trace.app_name, trace.workload))
             if calibration is not None else 1.0)
    return ReplayResult(
        app=trace.app_name,
        workload=trace.workload,
        predictor=predictor.name,
        cache_capacity=cache_capacity,
        policy=policy,
        dispatch=dispatch,
        # the engine's effective mode, not the requested flag: at capacity 0
        # there is no budget to share and the row must say so
        shared_budget=engine.shared_budget,
        precision=acc["precision"],
        recall=acc["recall"],
        evaluated=acc["evaluated"],
        coverage=covered / max(1, n_access),
        timely_coverage=engine.timely / max(1, engine.n_access),
        partial_hide=engine.partial / max(1, engine.n_access),
        stall_seconds=engine.stall_seconds,
        baseline_stall_seconds=baseline_stall_seconds,
        stall_saved_pct=saved,
        true_positives=acc["true_positives"],
        false_positives=acc["false_positives"],
        false_negatives=acc["false_negatives"],
        evictions=engine.evictions,
        thrash_misses=engine.thrash_misses,
        prefetch_loads=engine.prefetch_loads,
        writes=engine.writes,
        write_hits=engine.write_hits,
        dirty_evictions=engine.dirty_evictions,
        flushed_writes=engine.flushed_writes,
        batch_dispatches=engine.batch_dispatches,
        dedup_suppressed=engine.dedup_suppressed,
        stall_p50_s=p50 or 0.0,
        stall_p99_s=p99 or 0.0,
        stall_p999_s=p999 or 0.0,
        calib_scale=scale,
        calibrated_stall_s=engine.stall_seconds * scale,
        placement=getattr(store, "placement_name", "round-robin"),
        replication=getattr(store, "replication", 1),
        write_quorum=engine.write_quorum,
        scenario=scenario.name if scenario is not None else "no-fault",
        failovers=engine.failovers,
        overhead=overhead,
    )


def _calibration_app_key(app: str, workload: str) -> str:
    """Catalog key a result calibrates under — the mutating bank traversal
    is fitted separately (mirrors ``benchmarks/calibrate_latency.py``)."""
    return "bank_write" if workload == "setAllTransCustomers" else app


def evaluate_workload(
    wl: Workload,
    modes: Optional[Sequence[str]] = None,
    rop_depth: int = 2,
    config: Optional[SessionConfig] = None,
    cache_capacities: Sequence[int] = (0,),
    policies: Sequence[str] = (DEFAULT_POLICY,),
    shared_budget: bool = False,
    dispatch_modes: Sequence[str] = ("per-oid",),
    latency: LatencyModel = REPLAY,
    recorded: Optional[tuple[POSClient, int, list[RecordedTrace]]] = None,
    calibration=None,
    placement: str = "round-robin",
    replication: int = 1,
    scenarios: Sequence[str] = ("no-fault",),
    rfo: bool = True,
    write_quorums: Sequence[int] = (1,),
) -> list[ReplayResult]:
    """Record (train + eval runs), then replay every requested predictor
    under every (cache capacity, eviction policy, write quorum, dispatch
    mode, failure scenario) — miners warmed on the train run, everyone
    scored on the eval run.  ``rop_depth`` is only consulted when no ``config`` is supplied;
    pass ``recorded`` to reuse traces from ``record_catalog``.  Recording
    is placement-independent (the event stream is oids in program order),
    so one recorded trace replays under every placement/replication via
    ``rebuild_placement``; a crash scenario's crash time is anchored at a
    fraction of the *no-fault* baseline's completion time so the crash
    lands mid-run for every app."""
    from repro.pos.latency import make_scenario

    client, _root, traces = recorded if recorded is not None else record_workload(wl, runs=2)
    train, eval_ = traces[0], traces[-1]
    store = client.store
    if (placement != store.placement_name
            or replication != store.replication):
        store.rebuild_placement(placement, replication=replication)
    reg = client.logic_module.registered[wl.name]
    cfg = config if config is not None else SessionConfig(rop_depth=rop_depth)
    results = []
    for capacity in cache_capacities:
        for policy in policies:
            for wq in write_quorums:
                # the no-prefetch reference never dispatches: one baseline
                # serves every dispatch mode of this (capacity, policy,
                # quorum) cell
                nofault_baseline = replay_baseline(
                    eval_, store, latency=latency, cache_capacity=capacity,
                    policy=policy, shared_budget=shared_budget,
                    write_quorum=wq,
                )
                # crash-time anchor: the stall-free floor (think + hops) is
                # the one duration every replay of this cell shares — a
                # fraction of the *baseline* end would fall past the end of
                # a well-prefetched run (which finishes several times
                # faster) and never fire.  Quorum waits count as stall, so
                # the anchor is also quorum-invariant: every scenario fires
                # its faults at the same virtual instant across quorums.
                end_t = nofault_baseline.t - nofault_baseline.stall_seconds
                for scenario_name in scenarios:
                    scenario = make_scenario(scenario_name, end_t=end_t)
                    if not scenario.is_fault:
                        scenario = None
                        baseline = nofault_baseline.stall_seconds
                    else:
                        baseline = replay_baseline(
                            eval_, store, latency=latency,
                            cache_capacity=capacity,
                            policy=policy, shared_budget=shared_budget,
                            scenario=scenario, write_quorum=wq,
                        ).stall_seconds
                    for dispatch in dispatch_modes:
                        for mode in modes if modes is not None else available(kind="pos"):
                            predictor = make_pos_predictor(mode, config=cfg)
                            predictor.warm(train.accesses)
                            results.append(
                                replay(
                                    eval_,
                                    predictor,
                                    store,
                                    reg,
                                    latency=latency,
                                    cache_capacity=capacity,
                                    policy=policy,
                                    shared_budget=shared_budget,
                                    dispatch=dispatch,
                                    baseline_stall_seconds=baseline,
                                    calibration=calibration,
                                    scenario=scenario,
                                    rfo=rfo,
                                    write_quorum=wq,
                                )
                            )
    return results


def evaluate_apps(
    apps: Sequence[str] = ("bank", "bank_write", "wordcount", "kmeans"),
    modes: Optional[Sequence[str]] = None,
    rop_depth: int = 2,
    cache_capacities: Sequence[int] = (0,),
    policies: Sequence[str] = (DEFAULT_POLICY,),
    shared_budget: bool = False,
    dispatch_modes: Sequence[str] = ("per-oid",),
    latency: LatencyModel = REPLAY,
    trace_cache: Optional[str] = "default",
    calibration=None,
    calibrated: bool = False,
    placement: str = "round-robin",
    replication: int = 1,
    scenarios: Sequence[str] = ("no-fault",),
    rfo: bool = True,
    write_quorums: Sequence[int] = (1,),
) -> list[ReplayResult]:
    """``calibrated=True`` replays each app under its calibrated latency
    model (``calibration.calibrated_model``) instead of the raw REPLAY
    constants — virtual seconds then read directly as predicted wall
    seconds.  Off by default: the committed baseline.csv is recorded in
    raw virtual units."""
    catalog = _catalog()
    for name in apps:
        if name not in catalog:
            raise KeyError(f"unknown app {name!r}; catalog: {sorted(catalog)}")
    if calibration is None:
        # one loader, one source of truth: the fitted per-app scales come
        # from artifacts/predict/calibration.csv (identity when unfitted)
        from .calibration import load_calibration

        calibration = load_calibration()
    recorded = record_catalog([catalog[name] for name in apps],
                              cache_dir=_resolve_trace_cache(trace_cache))
    out: list[ReplayResult] = []
    wl_calibration = calibration
    for name in apps:
        wl_latency = latency
        if calibrated:
            from .calibration import Calibration, calibrated_model

            # catalog keys are the calibration table's app keys; the clock
            # itself is now in wall units, so the post-hoc column scale is
            # identity (calibrated_stall_s == stall_seconds, no re-scaling)
            wl_latency = calibrated_model(name, base=latency,
                                          calibration=calibration)
            wl_calibration = Calibration()
        out.extend(
            evaluate_workload(
                catalog[name],
                modes=modes,
                rop_depth=rop_depth,
                cache_capacities=cache_capacities,
                policies=policies,
                shared_budget=shared_budget,
                dispatch_modes=dispatch_modes,
                latency=wl_latency,
                recorded=recorded[name],
                calibration=wl_calibration,
                placement=placement,
                replication=replication,
                scenarios=scenarios,
                rfo=rfo,
                write_quorums=write_quorums,
            )
        )
    return out


# ---------------------------------------------------------------------------
# reporting / artifacts
# ---------------------------------------------------------------------------


_COLUMNS = (
    ("app", "{}"),
    ("workload", "{}"),
    ("predictor", "{}"),
    ("cache_capacity", "{}"),
    ("policy", "{}"),
    ("dispatch", "{}"),
    ("placement", "{}"),
    ("scenario", "{}"),
    ("precision", "{:.3f}"),
    ("recall", "{:.3f}"),
    ("coverage", "{:.3f}"),
    ("timely_coverage", "{:.3f}"),
    ("partial_hide", "{:.3f}"),
    ("stall_seconds", "{:.4f}"),
    ("stall_p50_s", "{:.4f}"),
    ("stall_p99_s", "{:.4f}"),
    ("stall_p999_s", "{:.4f}"),
    ("calibrated_stall_s", "{:.4f}"),
    ("baseline_stall_seconds", "{:.4f}"),
    ("stall_saved_pct", "{:.1f}"),
    ("evictions", "{}"),
    ("thrash_misses", "{}"),
    ("writes", "{}"),
    ("write_hits", "{}"),
    ("flushed_writes", "{}"),
    ("true_positives", "{}"),
    ("false_positives", "{}"),
    ("false_negatives", "{}"),
    ("table_bytes", "{}"),
    ("monitor_events", "{}"),
    ("late_predictions", "{}"),
    ("train_seconds", "{:.4f}"),
)

#: every flattened ReplayResult field, in CSV column order
CSV_COLUMNS = tuple(k for k, _ in _COLUMNS) + (
    "evaluated",
    "prefetch_loads",
    "predictions",
    "evicted_before_use",
    "hidden_seconds",
    "dirty_evictions",
    "protected_evictions",
    "shared_budget",
    "batch_dispatches",
    "dedup_suppressed",
    "calib_scale",
    "obs_seconds",
    "obs_events",
    # topology + failure-regime columns (placement/scenario are already in
    # _COLUMNS): keyed rows stay unique on the legacy key at the defaults
    "replication",
    "failovers",
    # partition-tolerant recovery columns: write-quorum pricing, hedged
    # demand reads, and readmission/anti-entropy accounting
    "write_quorum",
    "readmissions",
    "resync_lines",
    "hedged_reads",
    "hedge_wins",
    "quorum_writes",
    "quorum_acks",
    "quorum_retries",
    "quorum_failures",
    # static-optimizer columns (core.opt): read-for-ownership landings,
    # prefix-clipped collection expansions, mean static dispatch priority,
    # write-to-clean ownership round trips, and modeled executor-pool waits
    "rfo_prefetches",
    "truncated_hints",
    "hint_priority_mean",
    "ownership_upgrades",
    "exec_delayed",
)


def _fmt(value, fmt: str) -> str:
    return "-" if value is None else fmt.format(value)


def format_table(results: Sequence[ReplayResult]) -> str:
    rows = [[_fmt(r.row()[k], fmt) for k, fmt in _COLUMNS] for r in results]
    header = [k for k, _ in _COLUMNS]
    widths = [max(len(h), *(len(row[i]) for row in rows)) if rows else len(h)
              for i, h in enumerate(header)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(header, widths))]
    for row in rows:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
    return "\n".join(lines)


def write_csv(results: Sequence[ReplayResult], path: str) -> str:
    """Write the flattened result rows as a CSV artifact (undefined ratios
    become empty cells, never phantom zeros)."""
    import csv

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", newline="") as f:
        writer = csv.DictWriter(f, fieldnames=list(CSV_COLUMNS), extrasaction="ignore")
        writer.writeheader()
        for r in results:
            row = r.row()
            writer.writerow({k: ("" if row.get(k) is None else row.get(k, "")) for k in CSV_COLUMNS})
    return path


def _loadsim_main(args) -> None:
    """``--tenants N``: the virtual-clock mirror of benchmarks/loadgen.py.
    Deterministic for a given argument set — the committed loadgen.csv's
    virtual rows are byte-reproducible (wall_s cells stay empty)."""
    from .loadsim import run_loadsim, write_loadgen_csv

    capacities = [int(c) for c in args.cache_capacity.split(",") if c != ""]
    dispatch = args.dispatch.split(",")[0].strip() or "batch"
    report = run_loadsim(
        tenants=args.tenants, arrival=args.arrival, jobs=args.jobs,
        seed=args.seed, mode=args.mode, dispatch=dispatch,
        cache_capacity=capacities[0] if capacities else 128,
        shared_budget=args.shared_budget or not capacities,
        policy=args.cache_policy.split(",")[0],
        max_outstanding=args.max_outstanding,
        admission_threshold=args.admission_threshold,
        scenario=args.scenario.split(",")[0].strip() or "no-fault",
        replication=args.replication,
        write_quorum=int(args.write_quorum.split(",")[0] or 1),
    )
    agg = report.rows()[-1]
    print(f"# loadsim tenants={report.tenants} arrival={report.arrival} "
          f"mode={report.mode} dispatch={report.dispatch} "
          f"scenario={report.scenario}")
    print(f"#   ops={agg['ops']} mean_stall={agg['stall_mean_s']}s "
          f"fairness={report.fairness_ratio:.2f} "
          f"evicted_before_use={agg['evicted_before_use']} "
          f"shed={agg['admission_shed']} failovers={report.failovers}")
    if not args.no_csv:
        path = os.path.join(args.out, "loadgen.csv")
        write_loadgen_csv(path, report.rows(), append=args.append)
        print(f"# wrote {path} ({len(report.rows())} rows)")


def main(argv: Optional[list[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--apps", default="bank,bank_write,wordcount,kmeans,oo7,pga",
                    help="comma-separated app names from the catalog")
    ap.add_argument("--modes", default=None,
                    help="comma-separated predictor names (default: all registered)")
    ap.add_argument("--rop-depth", type=int, default=2)
    ap.add_argument("--cache-capacity", default="0",
                    help="comma-separated per-DS cache capacities to sweep (0 = unbounded)")
    ap.add_argument("--cache-policy", default=DEFAULT_POLICY,
                    help="comma-separated eviction policies to sweep "
                         "(lru, fifo, clock, lfu, prefetch-aware)")
    ap.add_argument("--shared-budget", action="store_true",
                    help="treat --cache-capacity as one global line budget drawn "
                         "on by all Data Services (policy-mediated stealing) "
                         "instead of a per-service capacity")
    ap.add_argument("--dispatch", default="per-oid,batch",
                    help="comma-separated dispatch modes to sweep (per-oid = one "
                         "executor submission per predicted oid; batch = one "
                         "deduped request per Data Service)")
    ap.add_argument("--placement", default="round-robin",
                    help="object placement policy to replay under "
                         "(round-robin, consistent-hash, locality); the "
                         "recorded traces re-place via rebuild_placement")
    ap.add_argument("--replication", type=int, default=1,
                    help="replication factor R (primary + ring successors); "
                         "crash scenarios need R >= 2 to complete")
    ap.add_argument("--scenario", default="no-fault",
                    help="comma-separated failure scenarios to sweep "
                         "(no-fault, straggler, crash, partition, "
                         "crash+revive, straggler+hedge)")
    ap.add_argument("--write-quorum", default="1",
                    help="comma-separated write quorums W to sweep: each "
                         "dirty write waits for W-of-R replica acks on the "
                         "app clock (1 = async/sloppy replication)")
    ap.add_argument("--calibrated", action="store_true",
                    help="replay each app under its calibrated latency model "
                         "(fitted scales from artifacts/predict/calibration.csv) "
                         "so virtual stalls read directly as predicted wall seconds")
    ap.add_argument("--no-rfo", action="store_true",
                    help="ignore read-for-ownership hint marks: prefetches "
                         "land clean and writes to them pay the ownership "
                         "round trip (the A/B control for core.opt pass 1)")
    ap.add_argument("--no-trace-cache", action="store_true",
                    help="always re-record workload traces instead of reusing "
                         "the disk-memoized ones under artifacts/predict/traces")
    ap.add_argument("--out", default="artifacts/predict",
                    help="directory for the CSV artifact (replay.csv)")
    ap.add_argument("--no-csv", action="store_true", help="print tables only")
    ap.add_argument("--fast", action="store_true",
                    help="only the fastest-to-trace apps (incl. the mutating bank run)")
    ap.add_argument("--tenants", type=int, default=0,
                    help="run the multi-tenant load simulation instead of the "
                         "single-tenant sweep: N concurrent sessions over one "
                         "shared store on the virtual clock (predict.loadsim); "
                         "writes <out>/loadgen.csv")
    ap.add_argument("--arrival", default="closed",
                    help="loadsim arrival process: 'closed' (exponential think "
                         "between jobs) or 'poisson:RATE' (open, aggregate RATE "
                         "jobs/s split across tenants)")
    ap.add_argument("--jobs", type=int, default=1,
                    help="loadsim jobs per tenant")
    ap.add_argument("--mode", default="capre",
                    help="loadsim predictor mode for every tenant")
    ap.add_argument("--max-outstanding", type=int, default=0,
                    help="loadsim admission-control bound (0 = unbounded); "
                         "mirrors PrefetchRuntime.admit on the modeled pool")
    ap.add_argument("--admission-threshold", type=float, default=0.0,
                    help="static priority that bypasses a full admission queue")
    ap.add_argument("--seed", type=int, default=0,
                    help="loadsim RNG seed (mix assignment, arrivals, think)")
    ap.add_argument("--append", action="store_true",
                    help="append loadsim rows to an existing loadgen.csv "
                         "(CI matrix legs share one artifact)")
    args = ap.parse_args(argv)
    if args.tenants > 0:
        _loadsim_main(args)
        return
    apps = ("bank", "bank_write", "wordcount", "kmeans") if args.fast else tuple(
        a for a in args.apps.split(",") if a
    )
    modes = tuple(m for m in args.modes.split(",") if m) if args.modes else None
    capacities = tuple(int(c) for c in args.cache_capacity.split(",") if c != "")
    policies = tuple(p for p in args.cache_policy.split(",") if p)
    dispatch_modes = tuple(d for d in args.dispatch.split(",") if d)
    scenarios = tuple(s for s in args.scenario.split(",") if s)
    write_quorums = tuple(int(w) for w in args.write_quorum.split(",") if w)
    results = evaluate_apps(
        apps=apps, modes=modes, rop_depth=args.rop_depth, cache_capacities=capacities,
        policies=policies, shared_budget=args.shared_budget,
        dispatch_modes=dispatch_modes,
        trace_cache=None if args.no_trace_cache else "default",
        calibrated=args.calibrated,
        placement=args.placement, replication=args.replication,
        scenarios=scenarios, rfo=not args.no_rfo,
        write_quorums=write_quorums,
    )
    print(format_table(results))
    if not args.no_csv:
        path = write_csv(results, os.path.join(args.out, "replay.csv"))
        print(f"# wrote {path} ({len(results)} rows)")


if __name__ == "__main__":
    main()
