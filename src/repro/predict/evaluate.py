"""Offline trace-replay evaluation harness (DESIGN.md section 3.3).

Turns ``ObjectStore.trace`` from a debugging aid into the substrate for a
head-to-head comparison of every registered predictor:

  1. **record** — run a benchmark workload with prefetching off, capturing
     the interleaved stream of method entries (the injected scheduling
     points) and application-path object accesses; two cold-cache runs are
     recorded so trace miners can train on the first and be scored on the
     second (the warm-up run a monitoring approach needs anyway);
  2. **replay** — feed the eval run's events to a fresh instance of each
     predictor: ``enter`` events drive ``on_method_entry``, ``access``
     events drive ``on_access`` (cold-cache misses are first accesses);
     the predicted oid set accumulates with no store I/O in the loop;
  3. **score** — precision/recall via the same ``prefetch_accuracy``
     definition the live store uses, plus **coverage** (the fraction of
     access events whose oid had already been predicted when the access
     happened — order-aware, unlike set recall) and the predictor's
     ``Overhead`` ledger (mined-table bytes, monitored events, train
     time — the costs the paper says the monitoring family pays).

Replay measures *prediction quality*, not I/O timing: a predicted object is
counted prefetched even if a real prefetch thread might have lost the race.
``benchmarks/bench_predictors.py`` is the end-to-end wall-clock companion.

Run: ``PYTHONPATH=src python -m repro.predict.evaluate [--fast] [--apps a,b]``
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.pos.client import POSClient, Session, SessionConfig
from repro.pos.store import prefetch_accuracy

from . import available, make_pos_predictor
from .base import Predictor


# ---------------------------------------------------------------------------
# recording
# ---------------------------------------------------------------------------


@dataclass
class RecordedTrace:
    """One cold-cache run of a workload: the interleaved event stream plus
    the plain oid trace (== what ``ObjectStore.trace`` recorded)."""

    app_name: str
    workload: str
    events: list[tuple]  # ("enter", method_key, oid) | ("access", oid)
    accesses: list[int]

    def __len__(self) -> int:
        return len(self.events)


class TraceRecorder(Predictor):
    """A predictor that predicts nothing and writes down everything —
    plugged into a Session to capture the replayable event stream."""

    def __init__(self):
        super().__init__()
        self.events: list[tuple] = []

    def bind(self, session) -> None:
        super().bind(session)
        session.store.access_listener = lambda oid: self.events.append(("access", oid))

    def on_method_entry(self, method_key: str, this_oid: int) -> list[int]:
        self.events.append(("enter", method_key, this_oid))
        return []


@dataclass
class Workload:
    """A benchmark app + a traversal to trace, in the same shape the
    benchmark driver uses (``run_once(session, root)``)."""

    name: str
    build_app: Callable
    populate: Callable[[object], int]
    run_once: Callable[[Session, int], None]
    workload: str = "run"


def _catalog() -> dict[str, Workload]:
    """The five paper benchmark apps with trace-friendly (small) sizes."""
    from repro.apps.bank import build_bank_app, populate_bank_store
    from repro.apps.kmeans import build_kmeans_app, initial_centroids, populate_kmeans
    from repro.apps.oo7 import build_oo7_app, populate_oo7
    from repro.apps.pga import build_pga_app, populate_pga
    from repro.apps.wordcount import build_wordcount_app, populate_wordcount

    cents = [list(c) for c in initial_centroids(k=3, dims=6)]
    return {
        "bank": Workload(
            "bank",
            build_bank_app,
            lambda store: populate_bank_store(store, n_transactions=60),
            lambda s, root: s.execute(root, "auditAll"),
            workload="auditAll",
        ),
        "wordcount": Workload(
            "wordcount",
            build_wordcount_app,
            lambda store: populate_wordcount(store, chunks_per_text=8, words_per_chunk=6),
            lambda s, root: s.execute(root, "run"),
        ),
        "kmeans": Workload(
            "kmeans",
            build_kmeans_app,
            lambda store: populate_kmeans(store, n_vectors=240, n_collections=3, dims=6),
            lambda s, root: s.execute(root, "run", cents),
        ),
        "oo7": Workload(
            "oo7",
            build_oo7_app,
            lambda store: populate_oo7(store, size="small"),
            lambda s, root: s.execute(root, "t1"),
            workload="t1",
        ),
        "pga": Workload(
            "pga",
            build_pga_app,
            lambda store: _pga_populate(store, populate_pga),
            lambda s, root: s.execute(root, "dfs"),
            workload="dfs",
        ),
    }


def _pga_populate(store, populate_pga) -> int:
    g, _src = populate_pga(store, n_vertices=120, out_degree=3)
    return g


def record_workload(
    wl: Workload, runs: int = 2, n_services: int = 4
) -> tuple[POSClient, int, list[RecordedTrace]]:
    """Populate a zero-latency store and record ``runs`` cold-cache traces
    of the workload with prefetching off.  Returns the live client (replay
    needs the object graph and the registration analysis) plus the traces."""
    client = POSClient(n_services=n_services)
    client.register(wl.build_app())
    root = wl.populate(client.store)
    traces: list[RecordedTrace] = []
    for _ in range(runs):
        client.store.reset_runtime_state()
        client.store.trace = []
        session = Session(client.store, client.logic_module.registered[wl.name])
        recorder = TraceRecorder()
        recorder.bind(session)
        session.predictor = recorder
        try:
            wl.run_once(session, root)
        finally:
            session.close()
        traces.append(
            RecordedTrace(
                app_name=wl.name,
                workload=wl.workload,
                events=list(recorder.events),
                accesses=list(client.store.trace),
            )
        )
        client.store.trace = None
    return client, root, traces


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------


@dataclass
class ReplayResult:
    app: str
    workload: str
    predictor: str
    precision: float
    recall: float
    coverage: float
    true_positives: int
    false_positives: int
    false_negatives: int
    overhead: dict = field(default_factory=dict)

    def row(self) -> dict:
        out = dict(self.__dict__)
        out.update(out.pop("overhead"))
        return out


def replay(trace: RecordedTrace, predictor: Predictor, store, reg) -> ReplayResult:
    """Drive ``predictor`` through the recorded event stream and score the
    oids it would have prefetched against the oids actually accessed."""
    predictor.attach(store, reg)
    predicted: set[int] = set()
    accessed: set[int] = set()
    n_access, timely = 0, 0
    for ev in trace.events:
        if ev[0] == "enter":
            _, key, oid = ev
            predicted.update(predictor.on_method_entry(key, oid))
        else:
            oid = ev[1]
            n_access += 1
            if oid in predicted:
                timely += 1
            accessed.add(oid)
            predicted.update(predictor.on_access(oid, store.cls_of(oid)))
    acc = prefetch_accuracy(predicted, accessed)
    return ReplayResult(
        app=trace.app_name,
        workload=trace.workload,
        predictor=predictor.name,
        precision=acc["precision"],
        recall=acc["recall"],
        coverage=timely / max(1, n_access),
        true_positives=acc["true_positives"],
        false_positives=acc["false_positives"],
        false_negatives=acc["false_negatives"],
        overhead=predictor.overhead.snapshot(),
    )


def evaluate_workload(
    wl: Workload,
    modes: Optional[Sequence[str]] = None,
    rop_depth: int = 2,
    config: Optional[SessionConfig] = None,
) -> list[ReplayResult]:
    """Record (train + eval runs), then replay every requested predictor —
    miners warmed on the train run, everyone scored on the eval run.
    ``rop_depth`` is only consulted when no ``config`` is supplied."""
    client, _root, traces = record_workload(wl, runs=2)
    train, eval_ = traces[0], traces[-1]
    reg = client.logic_module.registered[wl.name]
    cfg = config if config is not None else SessionConfig(rop_depth=rop_depth)
    results = []
    for mode in modes if modes is not None else available(kind="pos"):
        predictor = make_pos_predictor(mode, config=cfg)
        predictor.warm(train.accesses)
        results.append(replay(eval_, predictor, client.store, reg))
    return results


def evaluate_apps(
    apps: Sequence[str] = ("bank", "wordcount", "kmeans"),
    modes: Optional[Sequence[str]] = None,
    rop_depth: int = 2,
) -> list[ReplayResult]:
    catalog = _catalog()
    out: list[ReplayResult] = []
    for name in apps:
        if name not in catalog:
            raise KeyError(f"unknown app {name!r}; catalog: {sorted(catalog)}")
        out.extend(evaluate_workload(catalog[name], modes=modes, rop_depth=rop_depth))
    return out


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------


_COLUMNS = (
    ("app", "{}"),
    ("workload", "{}"),
    ("predictor", "{}"),
    ("precision", "{:.3f}"),
    ("recall", "{:.3f}"),
    ("coverage", "{:.3f}"),
    ("true_positives", "{}"),
    ("false_positives", "{}"),
    ("false_negatives", "{}"),
    ("table_bytes", "{}"),
    ("monitor_events", "{}"),
    ("train_seconds", "{:.4f}"),
)


def format_table(results: Sequence[ReplayResult]) -> str:
    rows = [[fmt.format(r.row()[k]) for k, fmt in _COLUMNS] for r in results]
    header = [k for k, _ in _COLUMNS]
    widths = [max(len(h), *(len(row[i]) for row in rows)) if rows else len(h)
              for i, h in enumerate(header)]
    lines = ["  ".join(h.ljust(w) for h, w in zip(header, widths))]
    for row in rows:
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths)))
    return "\n".join(lines)


def main(argv: Optional[list[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--apps", default="bank,wordcount,kmeans,oo7,pga",
                    help="comma-separated app names from the catalog")
    ap.add_argument("--modes", default=None,
                    help="comma-separated predictor names (default: all registered)")
    ap.add_argument("--rop-depth", type=int, default=2)
    ap.add_argument("--fast", action="store_true",
                    help="only the three fastest-to-trace apps")
    args = ap.parse_args(argv)
    apps = ("bank", "wordcount", "kmeans") if args.fast else tuple(
        a for a in args.apps.split(",") if a
    )
    modes = tuple(m for m in args.modes.split(",") if m) if args.modes else None
    results = evaluate_apps(apps=apps, modes=modes, rop_depth=args.rop_depth)
    print(format_table(results))


if __name__ == "__main__":
    main()
