"""Hybrid static + trace-mined predictor (GrASP-style, see PAPERS.md).

GrASP's observation is that static structure and learned history are
complementary: schema/code analysis is exact about *bulk* structure
(collections — where mis-prediction is most expensive and monitoring is
least informative, since element order varies), while learned predictors
shine on *branch-dependent* single navigations that static analysis must
either over-approximate (include policy) or drop (exclude policy).

So the hybrid splits the hint space:

  * **static part** — CAPre hints that traverse a collection are kept and
    scheduled at method entry exactly like ``static-capre`` (the injected
    closure, parallel fan-out over distributed collections);
  * **learned part** — everything else (single-association chains,
    branch-dependent navigations) is left to an order-k ``MarkovMiner``
    driven by the access listener.

Overhead is the sum of both parts — i.e. it pays the miner's monitoring
tax only for the single-association share of the workload.
"""

from __future__ import annotations

from typing import Optional, Sequence

from .base import Overhead, Predictor
from .markov import MarkovMiner
from .static_capre import StaticCapre


class Hybrid(Predictor):
    def __init__(self, config=None):
        super().__init__()
        self.config = config
        self.static = StaticCapre(config, hint_filter=lambda h: h.has_collection)
        self.miner = MarkovMiner(config)

    # -- lifecycle ----------------------------------------------------------

    def warm(self, trace: Sequence[int]) -> None:
        self.miner.warm(trace)

    def attach(self, store, reg) -> None:
        super().attach(store, reg)
        self.static.attach(store, reg)
        self.miner.store = store
        self.miner.reg = reg

    def bind(self, session) -> None:
        Predictor.bind(self, session)
        self.static.session = session
        self.miner.session = session
        self._listen(session.store, "access_listener", lambda oid: self.on_access(oid, None))
        if session.config is not None and session.config.warm_trace:
            self.miner.warm(session.config.warm_trace)

    def unbind(self) -> None:
        self.static.session = None
        self.miner.session = None
        super().unbind()

    # -- prediction ----------------------------------------------------------

    def on_method_entry(self, method_key: str, this_oid: int) -> list[int]:
        return self.static.on_method_entry(method_key, this_oid)

    def on_access(self, oid: int, cls: Optional[str]) -> list[int]:
        return self.miner.on_access(oid, cls)

    # -- accounting ------------------------------------------------------------

    @property
    def overhead(self) -> Overhead:  # type: ignore[override]
        s, m = self.static.overhead, self.miner.overhead
        return Overhead(
            table_bytes=s.table_bytes + m.table_bytes,
            monitor_events=s.monitor_events + m.monitor_events,
            train_seconds=s.train_seconds + m.train_seconds,
            predictions=s.predictions + m.predictions,
            late_predictions=s.late_predictions + m.late_predictions,
            evicted_before_use=s.evicted_before_use + m.evicted_before_use,
            hidden_seconds=s.hidden_seconds + m.hidden_seconds,
            protected_evictions=s.protected_evictions + m.protected_evictions,
            batch_dispatches=s.batch_dispatches + m.batch_dispatches,
            dedup_suppressed=s.dedup_suppressed + m.dedup_suppressed,
        )

    @overhead.setter
    def overhead(self, value: Overhead) -> None:
        # base __init__ assigns a fresh ledger; the hybrid's ledger is
        # derived from its parts, so the assignment is a no-op
        pass
