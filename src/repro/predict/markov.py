"""Trace-mined sequence predictor — the monitoring-based baseline.

Palpatine-style (PAPERS.md): mine frequent access sequences from recorded
``ObjectStore.trace``s into an order-k Markov table (context of up to the
last k accessed oids -> successor counts), then at runtime predict the
most likely continuation of the current access history and prefetch it.

This is exactly the regime the paper argues against, so its costs are
charged honestly on the ``Overhead`` ledger:

  * **memory** — the mined table is bounded (``table_capacity`` contexts);
    once full, new contexts are dropped (existing ones keep counting), and
    the resident size is reported as ``overhead.table_bytes``;
  * **CPU** — every application-path access is observed
    (``overhead.monitor_events``), each paying history-update + table
    lookups on the application thread.

Prediction: back-off from order k to order 1 until a context with
sufficiently confident successors is found; then greedily follow the top
successor chain up to ``chain`` steps (sequence prefetch, not just the
next object).
"""

from __future__ import annotations

import time
from collections import Counter, deque
from typing import Optional, Sequence

from repro.pos.trace import trace_oids

from .base import Predictor, table_bytes


class MarkovMiner(Predictor):
    def __init__(self, config=None, *, order: Optional[int] = None,
                 confidence: Optional[float] = None,
                 table_capacity: Optional[int] = None,
                 fanout: Optional[int] = None, chain: Optional[int] = None):
        super().__init__()

        def cfg(attr, override, default):
            if override is not None:
                return override
            return getattr(config, attr, default) if config is not None else default

        self.order = cfg("markov_order", order, 2)
        self.confidence = cfg("markov_confidence", confidence, 0.25)
        self.table_capacity = cfg("markov_table_capacity", table_capacity, 65536)
        self.fanout = cfg("markov_fanout", fanout, 8)
        self.chain = cfg("markov_chain", chain, 4)
        self._table: dict[tuple[int, ...], Counter] = {}
        self._history: deque[int] = deque(maxlen=self.order)
        self._issued: set[int] = set()
        self._dropped_contexts = 0

    # -- mining -------------------------------------------------------------

    def warm(self, trace: Sequence) -> None:
        t0 = time.perf_counter()
        # schema-v2 event traces carry writes and method entries; the miner
        # trains on the demand-path oid sequence (reads AND writes — the
        # Palpatine regime mines full get/put streams).  Bare-oid lists
        # pass through unchanged.
        trace = trace_oids(trace)
        for i in range(1, len(trace)):
            succ = trace[i]
            lo = max(0, i - self.order)
            for j in range(lo, i):
                ctx = tuple(trace[j:i])
                counts = self._table.get(ctx)
                if counts is None:
                    if len(self._table) >= self.table_capacity:
                        self._dropped_contexts += 1
                        continue
                    counts = self._table[ctx] = Counter()
                counts[succ] += 1
        self.overhead.train_seconds += time.perf_counter() - t0
        n_slots = len(self._table) + sum(len(c) for c in self._table.values())
        self.overhead.table_bytes = table_bytes(n_slots)

    # -- prediction ----------------------------------------------------------

    def _successors(self, ctx: tuple[int, ...]) -> list[int]:
        counts = self._table.get(ctx)
        if not counts:
            return []
        total = sum(counts.values())
        return [
            succ
            for succ, c in counts.most_common(self.fanout)
            if c / total >= self.confidence
        ]

    def _backoff(self, walk: Sequence[int]) -> list[int]:
        for k in range(min(self.order, len(walk)), 0, -1):
            nxt = self._successors(tuple(walk[-k:]))
            if nxt:
                return nxt
        return []

    def predict_next(self, history: Sequence[int]) -> list[int]:
        """Back-off prediction + greedy chain following: predict the likely
        immediate successors of ``history``, then extend the single most
        likely continuation up to ``chain`` more steps."""
        preds: list[int] = []
        seen: set[int] = set()
        for o in self._backoff(list(history)):
            if o not in seen:
                preds.append(o)
                seen.add(o)
        if preds:
            walk = list(history) + [preds[0]]
            for _ in range(self.chain):
                nxt = self._backoff(walk)
                if not nxt or nxt[0] in seen:
                    break
                preds.append(nxt[0])
                seen.add(nxt[0])
                walk.append(nxt[0])
        return preds

    # -- runtime hooks ---------------------------------------------------------

    def bind(self, session) -> None:
        super().bind(session)
        self._listen(session.store, "access_listener", lambda oid: self.on_access(oid, None))
        if session.config is not None and session.config.warm_trace:
            self.warm(session.config.warm_trace)

    def on_access(self, oid: int, cls: Optional[str]) -> list[int]:
        self.overhead.monitor_events += 1
        self._history.append(oid)
        preds = [
            o for o in self.predict_next(self._history) if o not in self._issued
        ]
        self._issued.update(preds)
        return self._emit(preds, context=f"access-{oid}")
