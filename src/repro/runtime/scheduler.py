"""Continuous-batching serving scheduler.

Production serving at pod scale keeps the decode batch full: finished
sequences release their KV-cache slot and queued requests are prefilled into
it while the other slots keep decoding (continuous batching).  This
scheduler implements the slot machinery over the Model prefill/decode steps:

  * a fixed pool of ``batch_size`` slots, each owning a segment of the
    static-shape KV cache;
  * per-slot position counters (sequences at different offsets decode in the
    same step — the attention mask is per-slot via kv_len);
  * admission: new requests are prefilled one-at-a-time into a free slot's
    cache segment (single-sequence prefill, batched decode — the standard
    disaggregation-lite layout);
  * completion by EOS token or max_new_tokens.

CAPre connection: the decode step's access plan is batch-shape-static, so
the scheduler's steady state keeps the prefetch schedule valid regardless
of request churn — exactly why the plan is derived per (shape, batch) and
not per request.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the scheduler
    output: list = field(default_factory=list)
    done: bool = False


@dataclass
class _Slot:
    busy: bool = False
    req: Optional[Request] = None
    pos: int = 0  # next write position in this slot's cache segment
    generated: int = 0


class ContinuousBatcher:
    """Slot-based continuous batching over a Model.

    The KV cache is [L, B, S_max, KV, hd]; slot i owns batch row i.  For
    simplicity each admitted prompt is prefilled with a batch-1 prefill and
    its cache rows are copied into the slot (real deployments run a
    dedicated prefill worker; the copy is the slot hand-off either way)."""

    def __init__(self, model, params, batch_size: int, max_len: int):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(batch_size)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        cfg = model.cfg
        kvdt = model.kv_dtype()
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        self.cache = {
            "k": jnp.zeros((L, batch_size, max_len, KV, hd), kvdt),
            "v": jnp.zeros((L, batch_size, max_len, KV, hd), kvdt),
        }
        self._decode = jax.jit(
            lambda p, c, t, lens: self._decode_step(p, c, t, lens)
        )
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b))
        self.steps = 0

    # -- batched decode with per-slot positions -----------------------------

    def _decode_step(self, params, cache, tokens, kv_lens):
        """One decode step where every slot sits at its own position.

        Uses the per-slot valid-length mask (kv_lens [B]) instead of a single
        scalar pos; new k/v are written at each slot's own position."""
        model, cfg = self.model, self.model.cfg
        from repro.models.layers import apply_norm, apply_rope, qkv_project, attn_output
        from repro.models.transformer import cfg_dtype, ffn_block

        dt = cfg_dtype(cfg)
        x = model.embed(params, tokens)
        B = tokens.shape[0]
        positions = kv_lens[:, None]  # [B, 1] current index per slot

        def body(h, inp):
            lp, kc, vc = inp
            hn = apply_norm(cfg.norm, h, lp["ln1"], lp.get("ln1_b"))
            q, k, v = qkv_project(hn, lp["attn"], cfg, dt)
            pos_arr = positions
            if cfg.rope == "mrope":
                pos_arr = jnp.broadcast_to(positions[None], (3, B, 1))
            q = apply_rope(cfg.rope, q, pos_arr, cfg.rope_theta)
            k = apply_rope(cfg.rope, k, pos_arr, cfg.rope_theta)
            # per-slot scatter of the new kv at its own position
            onehot = jax.nn.one_hot(kv_lens, kc.shape[1], dtype=kc.dtype)  # [B, S]
            kc = kc * (1 - onehot)[..., None, None] + onehot[..., None, None] * k.astype(kc.dtype)
            vc = vc * (1 - onehot)[..., None, None] + onehot[..., None, None] * v.astype(vc.dtype)
            # attend with per-slot valid length
            S = kc.shape[1]
            KV = cfg.n_kv_heads
            G = cfg.n_heads // KV
            q5 = q.reshape(B, 1, KV, G, cfg.head_dim)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q5, kc.astype(dt),
                           preferred_element_type=jnp.float32) / (cfg.head_dim ** 0.5)
            valid = jnp.arange(S)[None, :] <= kv_lens[:, None]  # [B, S]
            s = jnp.where(valid[:, None, None, None, :], s, -1e30)
            p = jax.nn.softmax(s, axis=-1).astype(dt)
            o = jnp.einsum("bkgqs,bskd->bqkgd", p, vc.astype(dt)).reshape(B, 1, cfg.q_dim)
            h = h + o.reshape(B, 1, cfg.n_heads, cfg.head_dim).reshape(B, 1, cfg.q_dim) @ lp["attn"]["wo"].astype(dt)
            h = ffn_block(h, lp, cfg, dt, None)
            return h, (kc, vc)

        h, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        h = model._final_norm(params, h)
        logits = model.logits(params, h)[..., : cfg.vocab_size]
        return logits, {"k": k_new, "v": v_new}

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.busy or not self.queue:
                continue
            req = self.queue.popleft()
            S = len(req.prompt)
            batch = {"inputs": jnp.asarray(req.prompt, jnp.int32)[None]}
            logits, cache1 = self._prefill(self.params, batch)
            # hand the prefilled rows to the slot's cache segment
            pad = self.max_len - cache1["k"].shape[2]
            for key in ("k", "v"):
                seg = jnp.pad(cache1[key], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                self.cache[key] = self.cache[key].at[:, i : i + 1].set(
                    seg.astype(self.cache[key].dtype)
                )
            slot.busy = True
            slot.req = req
            slot.pos = S
            slot.generated = 0
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
            slot.generated = 1

    # -- one engine tick -------------------------------------------------------

    def step(self) -> int:
        """Admit + one batched decode step. Returns number of active slots."""
        self._admit()
        active = [s for s in self.slots if s.busy]
        if not active:
            return 0
        tokens = np.zeros((self.B, 1), np.int32)
        lens = np.zeros((self.B,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.busy:
                tokens[i, 0] = slot.req.output[-1]
                lens[i] = slot.pos
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(lens)
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for i, slot in enumerate(self.slots):
            if not slot.busy:
                continue
            slot.pos += 1
            slot.generated += 1
            req = slot.req
            tok = int(nxt[i])
            req.output.append(tok)
            eos = req.eos_id is not None and tok == req.eos_id
            if eos or slot.generated >= req.max_new_tokens or slot.pos >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                slot.busy = False
                slot.req = None
        self.steps += 1
        return len([s for s in self.slots if s.busy])

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        while (self.queue or any(s.busy for s in self.slots)) and self.steps < max_steps:
            self.step()
        return self.finished
