"""Fault tolerance machinery for pod-scale runs.

Implements (and unit-tests, with simulated clocks and injected failures):

  * **HeartbeatMonitor** — workers post heartbeats; a monitor thread flags
    nodes that miss ``timeout`` seconds as failed and invokes the recovery
    callback once per incident;
  * **ElasticPlanner** — given the surviving device count, recompute the
    largest valid production mesh (full 16-wide model axis; data axis
    shrinks), the re-balanced per-shard batch, and whether a restore +
    re-shard is required (pairs with CheckpointManager's elastic restore);
  * **StragglerDetector** — per-step duration tracking with a robust
    (median + MAD) z-score; persistent stragglers trigger a mitigation hook
    (drop to spare / re-shard advice), the standard large-fleet mitigation;
  * **TrainSupervisor** — ties it together: run a step function under
    failure detection; on failure, shrink the mesh via the planner and
    resume from the last checkpoint.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class HeartbeatMonitor:
    def __init__(self, node_ids, timeout: float = 5.0, on_failure: Optional[Callable] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.on_failure = on_failure
        self.clock = clock
        self._last = {n: clock() for n in node_ids}
        self._failed: set = set()
        self._lock = threading.Lock()

    def beat(self, node_id) -> None:
        with self._lock:
            self._last[node_id] = self.clock()
            # a node that comes back is still considered failed until the
            # controller re-admits it explicitly

    def readmit(self, node_id) -> None:
        with self._lock:
            self._failed.discard(node_id)
            self._last[node_id] = self.clock()

    def check(self) -> list:
        """Returns newly failed nodes (invokes the callback once each)."""
        now = self.clock()
        newly = []
        with self._lock:
            for n, t in self._last.items():
                if n not in self._failed and now - t > self.timeout:
                    self._failed.add(n)
                    newly.append(n)
        for n in newly:
            if self.on_failure:
                self.on_failure(n)
        return newly

    @property
    def healthy(self) -> list:
        with self._lock:
            return [n for n in self._last if n not in self._failed]

    @property
    def failed(self) -> set:
        with self._lock:
            return set(self._failed)


@dataclass
class MeshPlan:
    data: int
    model: int
    pods: int = 1
    global_batch: int = 0

    @property
    def chips(self) -> int:
        return self.pods * self.data * self.model


class ElasticPlanner:
    """Recompute the mesh after losing nodes.

    Policy: the model axis is sacred (TP groups must stay whole: losing any
    chip of a 16-wide TP group kills the whole group), so recovery drops
    whole data-parallel rows; the global batch is kept by increasing the
    per-shard batch (grad accumulation) when divisible, else reduced to the
    nearest multiple.
    """

    def __init__(self, model_axis: int = 16, pods: int = 1):
        self.model_axis = model_axis
        self.pods = pods

    def plan(self, surviving_chips: int, global_batch: int) -> MeshPlan:
        rows = surviving_chips // self.model_axis
        if rows < 1:
            raise RuntimeError("fewer surviving chips than one model group")
        # keep pods only if every pod retains the same row count
        pods = self.pods if rows % self.pods == 0 else 1
        data = rows // pods
        batch = global_batch
        if batch % (pods * data):
            batch = (batch // (pods * data)) * (pods * data)
            batch = max(batch, pods * data)
        return MeshPlan(data=data, model=self.model_axis, pods=pods, global_batch=batch)


class StragglerDetector:
    """Robust per-node step-duration outlier detection (median + MAD)."""

    def __init__(self, threshold: float = 4.0, min_samples: int = 5, patience: int = 3):
        self.threshold = threshold
        self.min_samples = min_samples
        self.patience = patience
        self._durations: dict = {}
        self._strikes: dict = {}

    def record(self, node_id, seconds: float) -> None:
        self._durations.setdefault(node_id, []).append(seconds)

    def check(self) -> list:
        """Nodes whose last step is a persistent outlier."""
        lasts = {n: d[-1] for n, d in self._durations.items() if d}
        if len(lasts) < self.min_samples:
            return []
        vals = sorted(lasts.values())
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2] or 1e-9
        out = []
        for n, v in lasts.items():
            if (v - med) / (1.4826 * mad) > self.threshold:
                self._strikes[n] = self._strikes.get(n, 0) + 1
                if self._strikes[n] >= self.patience:
                    out.append(n)
            else:
                self._strikes[n] = 0
        return out


class StoreFaultDetector:
    """Failure detection for the persistent object store (the POS-side
    consumer of :class:`HeartbeatMonitor` + :class:`StragglerDetector`,
    which previously only served the training supervisor).

    Every landed disk load on Data Service *i* calls ``beat(i, seconds)``:
    the beat proves the service alive and the duration feeds the straggler
    baseline.  The store's demand path calls ``tick()`` periodically (every
    ``check_every`` beats/accesses, amortizing the O(services) scans):

      * services silent for ``heartbeat_timeout`` are reported to
        ``store._note_service_down`` — the *slow path* for crashes nobody
        tripped over (the fast path is the ``ServiceCrashed`` error);
      * persistent disk-time outliers go to ``store._note_straggler`` so
        replica routing deprioritizes them.
    """

    def __init__(self, store, heartbeat_timeout: float = 5.0,
                 clock: Callable[[], float] = time.monotonic,
                 straggler_threshold: float = 4.0,
                 straggler_min_samples: int = 4,
                 straggler_patience: int = 3,
                 check_every: int = 32):
        self.store = store
        self.monitor = HeartbeatMonitor(
            [ds.ds_id for ds in store.services],
            timeout=heartbeat_timeout,
            on_failure=store._note_service_down,
            clock=clock,
        )
        self.straggler = StragglerDetector(
            threshold=straggler_threshold,
            min_samples=straggler_min_samples,
            patience=straggler_patience,
        )
        self.check_every = max(1, check_every)
        self._lock = threading.Lock()
        self._calls = 0

    def beat(self, ds_id, seconds: Optional[float] = None) -> None:
        self.monitor.beat(ds_id)
        if seconds is not None:
            with self._lock:
                self.straggler.record(ds_id, seconds)

    def readmit(self, ds_id) -> None:
        """Re-admit a returned service: heartbeat bookkeeping resets AND its
        straggler history is dropped — a readmitted service starts with a
        clean disk-time baseline instead of being instantly re-flagged on
        the strikes it accumulated while degraded."""
        self.monitor.readmit(ds_id)
        with self._lock:
            self.straggler._durations.pop(ds_id, None)
            self.straggler._strikes.pop(ds_id, None)

    def tick(self, force: bool = False) -> None:
        """Amortized detection scan; ``force`` runs it regardless of the
        call counter (tests, end-of-run sweeps)."""
        with self._lock:
            self._calls += 1
            if not force and self._calls % self.check_every:
                return
            flagged = self.straggler.check()
        self.monitor.check()  # invokes _note_service_down on newly silent
        for ds_id in flagged:
            self.store._note_straggler(ds_id)


@dataclass
class SupervisorReport:
    steps_completed: int = 0
    failures_handled: int = 0
    restores: int = 0
    final_chips: int = 0
    events: list = field(default_factory=list)


class TrainSupervisor:
    """Drives a (simulated or real) training loop under failure injection.

    ``step_fn(step_index, mesh_plan) -> None`` may raise ``NodeFailure`` to
    simulate a lost worker; the supervisor shrinks the mesh and resumes from
    the last checkpoint step."""

    def __init__(self, planner: ElasticPlanner, checkpoint_mgr, save_every: int = 10):
        self.planner = planner
        self.ckpt = checkpoint_mgr
        self.save_every = save_every

    def run(self, step_fn, state, total_steps: int, chips: int, global_batch: int) -> SupervisorReport:
        report = SupervisorReport()
        plan = self.planner.plan(chips, global_batch)
        step = 0
        self.ckpt.save(0, state, wait=True)
        last_saved = 0
        while step < total_steps:
            try:
                state = step_fn(step, plan, state)
                step += 1
                report.steps_completed += 1
                if step % self.save_every == 0:
                    self.ckpt.save(step, state, wait=True)
                    last_saved = step
            except NodeFailure as f:
                report.failures_handled += 1
                chips -= f.lost_chips
                plan = self.planner.plan(chips, global_batch)
                report.events.append(
                    f"step {step}: lost {f.lost_chips} chips -> mesh {plan.pods}x{plan.data}x{plan.model}"
                )
                step, state = self.ckpt.restore(like=state)
                report.restores += 1
        report.final_chips = plan.chips
        return report


class NodeFailure(Exception):
    def __init__(self, lost_chips: int = 16):
        super().__init__(f"lost {lost_chips} chips")
        self.lost_chips = lost_chips
