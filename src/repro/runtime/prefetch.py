"""Plan-driven weight streaming — CAPre's prefetch executor on the tensor
store (DESIGN.md section 2).

The "persistent object store" here is host DRAM holding offloaded
parameters; the "application" is a layer-by-layer step execution.  Like the
paper's injected prefetch methods:

  * a **background executor** walks the PrefetchPlan (derived statically by
    ``core.access_plan``) and issues host->device copies ``k_ahead`` groups
    ahead of the compute frontier — zero runtime monitoring;
  * **collections** (stacked layer weights) fan out over a parallel pool —
    the paper's parallelStream() over a distributed collection;
  * the **ROP baseline** only ever fetches the next ``depth`` directly
    referenced groups when a group is entered (schema-only, no plan), and
    never streams collections ahead.

On real hardware the fetch is a ``jax.device_put`` onto the TPU; here the
host store models transfer latency so the overlap accounting is real.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.access_plan import AccessRecord, PrefetchPlan

#: the process id the streamer's spans render under in a merged Perfetto
#: timeline (Data Services own pids 0..n-1; the streamer is its own
#: producer track — exporters label it via ``process_names``)
STREAM_PID = 9000


@dataclass
class StreamMetrics:
    fetches: int = 0
    prefetch_hits: int = 0
    stalls: int = 0
    stall_seconds: float = 0.0
    bytes_moved: int = 0
    wasted_bytes: int = 0  # prefetched but never used
    batch_dispatches: int = 0  # pool submissions made by batched group fetches
    dedup_suppressed: int = 0  # paths suppressed pre-submission (cached/in-flight)
    fetch_timeouts: int = 0  # in-flight waits that expired; served via sync fallback
    hedged_fetches: int = 0  # straggling in-flight waits raced by a sync fetch
    hedge_wins: int = 0  # hedged fetches that beat the straggling lane


class HostParamStore:
    """Host-DRAM parameter store with modeled host->device bandwidth."""

    def __init__(self, params: dict, bandwidth_gbps: float = 8.0, base_latency_s: float = 200e-6):
        import jax

        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        from repro.core.access_plan import _path_str

        self.arrays = {_path_str(p): np.asarray(v) for p, v in leaves}
        self.bandwidth = bandwidth_gbps * 1e9
        self.base_latency = base_latency_s

    def fetch(self, path: str) -> np.ndarray:
        arr = self.arrays[path]
        time.sleep(self.base_latency + arr.nbytes / self.bandwidth)
        return arr

    def nbytes(self, path: str) -> int:
        return self.arrays[path].nbytes


class WeightStreamer:
    """Streams parameter groups onto the device ahead of use.

    ``mode`` resolves through the ``repro.predict`` registry to a
    ``StreamPolicy`` (None = fetch on demand, every use stalls):

      * "capre": follows the PrefetchPlan order, ``k_ahead`` groups ahead,
        collections fanned out on the parallel pool;
      * "rop":   when a group is entered, fetch the next ``rop_depth``
        groups in tree order (schema heuristic, plan-blind);
      * "markov-miner" / "hybrid": trace-mined group transitions — warm
        them with ``warm_group_trace`` (the ``group_log`` of a prior run).

    ``dispatch`` mirrors ``ObjectStore``'s A/B knob: ``"batch"`` (default)
    pipelines each plan group through at most ``workers`` strided lanes,
    ``"per-oid"`` submits one pool task per path (the legacy reference).
    Passing a ``repro.obs.Registry`` adopts :class:`StreamMetrics` as a
    snapshot source and records every ``get`` wait into a
    ``stream_stall_s`` histogram (0.0 for prefetch hits).

    Passing a ``repro.obs.Tracer`` records the same lifecycle spans the
    ObjectStore emits (predicted -> dispatched -> claimed -> loaded ->
    hit/partial/miss), with ``service=STREAM_PID`` so the streamer renders
    as its own producer track in a merged Perfetto timeline.  Give the
    streamer its OWN tracer — its path-derived ids share an oid space with
    nothing else.  ``path_ids`` maps path -> span oid for labeling.
    """

    def __init__(
        self,
        store: HostParamStore,
        plan: Optional[PrefetchPlan] = None,
        mode: Optional[str] = "capre",
        k_ahead: int = 2,
        rop_depth: int = 1,
        workers: int = 4,
        warm_group_trace: Optional[list] = None,
        dispatch: str = "batch",
        registry=None,
        tracer=None,
        fetch_timeout: float = 30.0,
        hedge_delay: float = 0.0,
    ):
        self.store = store
        self.plan = plan
        self.mode = mode
        self.k_ahead = k_ahead
        self.rop_depth = rop_depth
        self.dispatch = dispatch
        self.metrics = StreamMetrics()
        self._stall_hist = None
        if registry is not None:
            from dataclasses import asdict

            registry.register_source("stream", lambda: asdict(self.metrics))
            self._stall_hist = registry.histogram("stream_stall_s")
        self.tracer = tracer
        self.path_ids: dict[str, int] = {}
        self._cache: dict[str, np.ndarray] = {}
        self._inflight: dict[str, threading.Event] = {}
        self._used: set[str] = set()  # paths actually served to compute
        self._lock = threading.Lock()
        self._workers = max(1, workers)
        self._pool = ThreadPoolExecutor(max_workers=self._workers,
                                        thread_name_prefix="stream")
        self.fetch_timeout = fetch_timeout
        # hedged fetches (0.0 = off): a get() waiting on an in-flight lane
        # gives it hedge_delay seconds, then races it with a synchronous
        # fetch and serves whichever copy lands first — the streaming
        # analogue of the ObjectStore's hedged demand reads
        self.hedge_delay = hedge_delay
        self._groups = self._group_order()
        self._done = False
        self.group_log: list[int] = []  # entered group indices (miner food)
        self._policy = None
        if mode is not None:
            from repro import predict

            self._policy = predict.make_stream_policy(mode)
            if warm_group_trace:
                self._policy.warm(warm_group_trace)

    # -- grouping ------------------------------------------------------------

    def _group_order(self) -> list[list[AccessRecord]]:
        """Execution-ordered groups of records (one group per first_use
        cluster — for a layer-scanned model: embed, layers, head...)."""
        if self.plan is None:
            return []
        ordered = self.plan.ordered()
        groups: list[list[AccessRecord]] = []
        for r in ordered:
            if groups and r.first_use == groups[-1][0].first_use:
                groups[-1].append(r)
            else:
                groups.append([r])
        return groups

    # -- fetch machinery --------------------------------------------------------

    def _span_id(self, path: str) -> int:
        """Stable int id for a path's lifecycle spans (PrefetchSpan keys on
        int oids; the streamer's ids are only unique within its own
        tracer)."""
        with self._lock:
            sid = self.path_ids.get(path)
            if sid is None:
                sid = len(self.path_ids)
                self.path_ids[path] = sid
            return sid

    def _disk_s(self, path: str) -> float:
        """Modeled transfer seconds for hidden/stall attribution."""
        base = getattr(self.store, "base_latency", 0.0)
        bw = getattr(self.store, "bandwidth", 0.0)
        try:
            nbytes = self.store.nbytes(path)
        except Exception:
            return base
        return base + (nbytes / bw if bw else 0.0)

    def _fetch_async(self, path: str) -> None:
        with self._lock:
            if path in self._cache or path in self._inflight:
                return
            ev = threading.Event()
            self._inflight[path] = ev

        def work():
            arr = self.store.fetch(path)
            with self._lock:
                self._cache[path] = arr
                self.metrics.fetches += 1
                self.metrics.bytes_moved += arr.nbytes
                self._inflight.pop(path, None)
            ev.set()

        self._pool.submit(work)

    def fetch_group(self, paths) -> None:
        """Batched prefetch of one plan group: dedupe every path against
        cache and in-flight fetches under ONE lock snapshot (the per-record
        fan-out paid a lock round trip and a pool submission per path), then
        pipeline the survivors through at most ``workers`` lanes — strided,
        so the earliest-needed records start first on every lane.  This is
        the streaming analogue of ``ObjectStore.prefetch_batch``.

        Under ``dispatch="per-oid"`` the same request instead pays one lock
        round trip and one pool submission per path — the reference arm of
        the dispatch A/B (``benchmarks.bench_streaming``)."""
        paths = list(paths)
        tr = self.tracer
        if tr is not None and paths:
            tr.predicted([self._span_id(p) for p in paths],
                         origin=f"stream:{self.mode}")
        if self.dispatch == "per-oid":
            for path in paths:
                with self._lock:
                    if path in self._cache or path in self._inflight:
                        self.metrics.dedup_suppressed += 1
                        suppressed = True
                    else:
                        self._inflight[path] = threading.Event()
                        self.metrics.batch_dispatches += 1
                        suppressed = False
                if suppressed:
                    if tr is not None:
                        tr.suppressed([self._span_id(path)], STREAM_PID)
                    continue
                if tr is not None:
                    # claiming = winning the in-flight dedupe, which just
                    # happened under the lock (unlike the ObjectStore there
                    # is no separate per-service claim step)
                    sid = self._span_id(path)
                    tr.dispatched([sid], STREAM_PID, tr.new_batch())
                    tr.claimed([sid], STREAM_PID)
                self._pool.submit(self._fetch_lane, [path])
            return
        todo: list[str] = []
        sup: list[str] = []
        with self._lock:
            for path in paths:
                if path in self._cache or path in self._inflight or path in todo:
                    self.metrics.dedup_suppressed += 1
                    sup.append(path)
                    continue
                self._inflight[path] = threading.Event()
                todo.append(path)
        if tr is not None and sup:
            tr.suppressed([self._span_id(p) for p in sup], STREAM_PID)
        if not todo:
            return
        if tr is not None:
            ids = [self._span_id(p) for p in todo]
            tr.dispatched(ids, STREAM_PID, tr.new_batch())
            # claiming = winning the in-flight dedupe above (no separate
            # per-service claim step in the streamer)
            tr.claimed(ids, STREAM_PID)
        lanes = max(1, min(self._workers, len(todo)))
        with self._lock:
            self.metrics.batch_dispatches += lanes
        for i in range(lanes):
            self._pool.submit(self._fetch_lane, todo[i::lanes], i)

    def _fetch_lane(self, paths: list[str], lane: int = 0) -> None:
        tr = self.tracer
        for i, path in enumerate(paths):
            sid = self._span_id(path) if tr is not None else -1
            queued = time.perf_counter()
            try:
                arr = self.store.fetch(path)
            except BaseException:
                # release EVERY remaining claim, not just the failing one —
                # a stranded in-flight entry would pin each later path's
                # get() on a dead event (they fall back to _fetch_async)
                with self._lock:
                    evs = [self._inflight.pop(p, None) for p in paths[i:]]
                for ev in evs:
                    if ev is not None:
                        ev.set()
                if tr is not None:
                    tr.dropped([self._span_id(p) for p in paths[i:]],
                               "stream-fetch-error")
                raise
            done = time.perf_counter()
            with self._lock:
                self._cache[path] = arr
                self.metrics.fetches += 1
                self.metrics.bytes_moved += arr.nbytes
                ev = self._inflight.pop(path, None)
            if tr is not None:
                # the pool lane is the slot: no separate slot wait here
                tr.loaded([sid], STREAM_PID, lane, queued, queued, done)
            if ev is not None:
                ev.set()

    def get(self, path: str) -> np.ndarray:
        """Blocking access from the compute thread."""
        tr = self.tracer
        with self._lock:
            arr = self._cache.get(path)
            ev = self._inflight.get(path)
            self._used.add(path)
        if arr is not None:
            self.metrics.prefetch_hits += 1
            if self._stall_hist is not None:
                self._stall_hist.record(0.0)
            if tr is not None:
                tr.demand(self._span_id(path), STREAM_PID,
                          time.perf_counter(), 0.0, full_load=False,
                          disk_load_s=self._disk_s(path))
            return arr
        t0 = time.perf_counter()
        was_inflight = ev is not None
        if ev is None:
            self._fetch_async(path)
            with self._lock:
                ev = self._inflight.get(path)
        landed, hedge_arr = True, None
        if ev is not None:
            if was_inflight and self.hedge_delay > 0:
                # hedged fetch: give the straggling lane hedge_delay to
                # land, then race it synchronously — first copy serves
                landed = ev.wait(timeout=min(self.hedge_delay,
                                             self.fetch_timeout))
                if not landed:
                    with self._lock:
                        self.metrics.hedged_fetches += 1
                    hedge_arr = self.store.fetch(path)
                    landed = ev.is_set()
            else:
                landed = ev.wait(timeout=self.fetch_timeout)
        with self._lock:
            arr = self._cache.get(path)
            if arr is None and hedge_arr is not None:
                # the hedge beat the lane: land + serve its copy (the lane
                # will overwrite the cache entry later, idempotently)
                self.metrics.hedge_wins += 1
                self.metrics.fetches += 1
                self.metrics.bytes_moved += hedge_arr.nbytes
                self._cache[path] = arr = hedge_arr
                landed = True
        if not landed or arr is None:
            # The in-flight wait expired (or the fetch errored and released
            # its event without landing anything): the old code did
            # ``self._cache[path]`` here and turned a slow lane into a bare
            # KeyError after the timeout.  Serve the compute thread with a
            # synchronous fetch instead — correctness over latency — and
            # count the incident so a saturated pool is visible.
            arr = self.store.fetch(path)
            with self._lock:
                self._cache[path] = arr
                self.metrics.fetches += 1
                self.metrics.bytes_moved += arr.nbytes
                if not landed:
                    self.metrics.fetch_timeouts += 1
            was_inflight = False  # the demand path did the full load itself
        stall = time.perf_counter() - t0
        self.metrics.stalls += 1
        self.metrics.stall_seconds += stall
        if self._stall_hist is not None:
            self._stall_hist.record(stall)
        if tr is not None:
            tr.demand(self._span_id(path), STREAM_PID, t0, stall,
                      full_load=not was_inflight,
                      disk_load_s=self._disk_s(path))
        return arr

    # -- the injected scheduling points ------------------------------------------

    def on_group_start(self, group_index: int) -> None:
        """Called when the compute frontier enters group ``group_index`` —
        the analogue of the injected prefetch-method invocation.  Delegates
        to the registry-resolved stream policy."""
        self.group_log.append(group_index)
        if self._policy is not None:
            self._policy.on_group_start(self, group_index)

    def run_plan(self, compute_s_per_group: float = 0.0,
                 compute_fn: Optional[Callable[[int, dict], None]] = None) -> float:
        """Execute the plan end to end: for each group, prefetch-ahead fires,
        then the compute thread `get`s every record in the group (stalling
        on misses) and runs the group compute.  Returns wall seconds."""
        t0 = time.perf_counter()
        if self._policy is not None:
            self.on_group_start(-1)
        for gi, group in enumerate(self._groups):
            arrays = {}
            for rec in group:
                arrays[rec.path] = self.get(rec.path)
            self.on_group_start(gi)
            if compute_fn is not None:
                compute_fn(gi, arrays)
            elif compute_s_per_group:
                time.sleep(compute_s_per_group)
            self._evict_before(gi)
        wall = time.perf_counter() - t0
        with self._lock:
            for p, a in self._cache.items():
                if p not in self._used:
                    self.metrics.wasted_bytes += a.nbytes
        return wall

    def _evict_before(self, gi: int) -> None:
        """Free groups already consumed (bounded device memory).  An evicted
        array that was prefetched but never served to compute is waste —
        charged here, where it leaves the cache, so prefetched-then-evicted
        mistakes are not invisible to the accounting."""
        if gi < 1:
            return
        with self._lock:
            for rec in self._groups[gi - 1]:
                arr = self._cache.pop(rec.path, None)
                if arr is not None and rec.path not in self._used:
                    self.metrics.wasted_bytes += arr.nbytes
                # usage is per-residency: once evicted, a re-prefetch of the
                # same path must be served again to count as useful
                self._used.discard(rec.path)

    def close(self) -> None:
        self._pool.shutdown(wait=True, cancel_futures=True)
        if self.tracer is not None:
            # prefetched-but-never-demanded spans terminate as dropped so
            # the exported timeline passes the one-terminal-state invariant
            self.tracer.drop_active("stream-closed")
