"""Low-overhead metrics: counters, gauges, log-bucketed histograms and the
named, labeled :class:`Registry` that owns them.

Design constraints (ISSUE 6):

  * **hot-path cheap** — recording a sample is a handful of dict/list ops
    under a per-metric lock; callers on the demand path pre-resolve their
    metric objects once and call ``record``/``inc`` directly;
  * **two fidelity regimes** — the virtual clock can afford exact
    percentiles (samples are kept and sorted on read), the wall clock keeps
    fixed log-spaced buckets only (p50/p99/p999 are bucket estimates);
  * **self-metering** — every recording charges its own wall cost to a
    shared :class:`Meter`, so the observability layer can report what *it*
    cost and the zero-overhead claim stays falsifiable;
  * **one snapshot** — pre-existing metric surfaces (``StoreMetrics``,
    ``StreamMetrics``, ``Overhead``) plug in as *sources* so one
    ``Registry.snapshot()`` returns everything a run measured.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence


@dataclass
class Meter:
    """Accumulated cost of the instrumentation itself."""

    seconds: float = 0.0
    events: int = 0

    def reset(self) -> None:
        self.seconds = 0.0
        self.events = 0


class Counter:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"labels": dict(self.labels), "value": self.value}

    def reset(self) -> None:
        self.value = 0


class Gauge:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self) -> dict:
        return {"labels": dict(self.labels), "value": self.value}

    def reset(self) -> None:
        self.value = 0.0


def log_buckets(lo: float = 1e-6, hi: float = 100.0, per_decade: int = 8) -> list[float]:
    """Ascending upper bucket edges, log-spaced ``per_decade`` per decade
    from ``lo`` to ``hi`` inclusive.  Bucket 0 is the implicit ``[0, lo)``
    underflow (where a fully hidden / cache-hit stall of 0.0 lands), and an
    implicit overflow bucket catches everything ``>= hi``."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return [lo * 10 ** (i / per_decade) for i in range(n + 1)]


class Histogram:
    """Latency histogram over fixed log-spaced buckets.

    ``exact=True`` (the virtual-clock regime) additionally keeps every raw
    sample so ``percentile`` returns the exact numpy-style (linear
    interpolation) quantile; ``exact=False`` (wall clock) answers from the
    buckets alone — the estimate is the geometric midpoint of the bucket
    containing the requested rank, i.e. within one bucket width (a factor
    of ``10**(1/per_decade)``) of the truth."""

    def __init__(self, name: str = "", labels: Optional[dict] = None,
                 lo: float = 1e-6, hi: float = 100.0, per_decade: int = 8,
                 exact: bool = False, meter: Optional[Meter] = None):
        self.name = name
        self.labels = labels or {}
        self.exact = exact
        self.meter = meter
        self._edges = log_buckets(lo, hi, per_decade)
        # counts[0] = underflow [0, lo); counts[-1] = overflow [hi, inf)
        self._counts = [0] * (len(self._edges) + 1)
        self._samples: list[float] = []
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0

    def _bucket_index(self, v: float) -> int:
        edges = self._edges
        if v < edges[0]:
            return 0
        if v >= edges[-1]:
            return len(edges)
        # log-spaced edges: the index is a closed-form log, clamped for
        # float-rounding safety (no bisect on the hot path)
        lo = edges[0]
        per = len(edges) - 1
        i = int(math.log10(v / lo) * per / math.log10(edges[-1] / lo)) + 1
        while i < len(edges) and v >= edges[i]:
            i += 1
        while i > 0 and v < edges[i - 1]:
            i -= 1
        return i

    def record(self, value: float) -> None:
        t0 = time.perf_counter() if self.meter is not None else 0.0
        v = value if value > 0.0 else 0.0
        with self._lock:
            self._counts[self._bucket_index(v)] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if self.exact:
                self._samples.append(v)
        m = self.meter
        if m is not None:
            m.events += 1
            m.seconds += time.perf_counter() - t0

    # -- read side ----------------------------------------------------------

    def percentile(self, q: float) -> Optional[float]:
        """Quantile ``q`` in [0, 1].  Exact (numpy 'linear') when samples
        are kept; bucket-estimated otherwise.  None when empty."""
        with self._lock:
            if not self.count:
                return None
            if self.exact:
                xs = sorted(self._samples)
                pos = q * (len(xs) - 1)
                lo_i = int(math.floor(pos))
                hi_i = min(lo_i + 1, len(xs) - 1)
                frac = pos - lo_i
                return xs[lo_i] * (1.0 - frac) + xs[hi_i] * frac
            rank = q * self.count
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= rank:
                    return self._bucket_estimate(i)
            return self._bucket_estimate(len(self._counts) - 1)

    def _bucket_estimate(self, i: int) -> float:
        if i == 0:
            return 0.0
        if i >= len(self._edges):
            return self.max  # overflow: best available bound
        lo = self._edges[i - 1]
        hi = self._edges[i]
        return math.sqrt(lo * hi)

    def percentiles(self, qs: Sequence[float] = (0.5, 0.99, 0.999)) -> list[Optional[float]]:
        return [self.percentile(q) for q in qs]

    def merge_from(self, other: "Histogram") -> None:
        """Pool another histogram's population into this one (same bucket
        layout required) — how per-service histograms aggregate to one
        store-wide distribution."""
        with other._lock:
            counts = list(other._counts)
            samples = list(other._samples)
            count, total = other.count, other.sum
            mn, mx = other.min, other.max
        with self._lock:
            if len(counts) != len(self._counts):
                raise ValueError("histogram bucket layouts differ")
            for i, c in enumerate(counts):
                self._counts[i] += c
            self.count += count
            self.sum += total
            self.min = min(self.min, mn)
            self.max = max(self.max, mx)
            if self.exact:
                self._samples.extend(samples)

    def snapshot(self) -> dict:
        with self._lock:
            out = {
                "labels": dict(self.labels),
                "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "exact": self.exact,
            }
        for q, key in ((0.5, "p50"), (0.99, "p99"), (0.999, "p999")):
            out[key] = self.percentile(q)
        return out

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * len(self._counts)
            self._samples = []
            self.count = 0
            self.sum = 0.0
            self.min = math.inf
            self.max = 0.0


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class Registry:
    """Named, labeled metrics plus pluggable snapshot *sources*.

    ``counter``/``gauge``/``histogram`` are get-or-create (same name +
    labels returns the same object), so hosts resolve their metric objects
    once at attach time and the hot path never hits the registry again.
    ``register_source`` adopts a legacy metric surface (anything with a
    callable returning a dict) so ``snapshot()`` is the one coherent read
    of everything a run measured, and ``reset()`` the one zeroing."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[tuple, Counter] = {}
        self._gauges: dict[tuple, Gauge] = {}
        self._histograms: dict[tuple, Histogram] = {}
        self._sources: dict[str, tuple[Callable[[], dict], Optional[Callable[[], None]]]] = {}
        self.meter = Meter()

    # -- get-or-create -------------------------------------------------------

    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        with self._lock:
            if key not in self._counters:
                self._counters[key] = Counter(name, labels)
            return self._counters[key]

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        with self._lock:
            if key not in self._gauges:
                self._gauges[key] = Gauge(name, labels)
            return self._gauges[key]

    def histogram(self, name: str, exact: bool = False, **labels) -> Histogram:
        key = (name, _label_key(labels))
        with self._lock:
            if key not in self._histograms:
                self._histograms[key] = Histogram(
                    name, labels, exact=exact, meter=self.meter
                )
            return self._histograms[key]

    def register_source(self, name: str, snapshot_fn: Callable[[], dict],
                        reset_fn: Optional[Callable[[], None]] = None) -> None:
        with self._lock:
            self._sources[name] = (snapshot_fn, reset_fn)

    def unregister_source(self, name: str) -> bool:
        """Remove a snapshot source (the inverse ``register_source`` never
        had): a closed ``Session`` must drop its ``runtime/<label>`` entry,
        or every snapshot keeps calling a snapshot_fn that pins a shut-down
        ``PrefetchRuntime`` forever.  Returns whether the name was
        registered."""
        with self._lock:
            return self._sources.pop(name, None) is not None

    def source_names(self) -> list[str]:
        with self._lock:
            return sorted(self._sources)

    # -- aggregation ---------------------------------------------------------

    def merged_histogram(self, name: str) -> Optional[Histogram]:
        """One pooled histogram across every labeled instance of ``name``
        (e.g. the store-wide stall distribution over per-service labels)."""
        with self._lock:
            parts = [h for (n, _), h in self._histograms.items() if n == name]
        if not parts:
            return None
        merged = Histogram(name, {"merged": True}, exact=all(p.exact for p in parts))
        for p in parts:
            merged.merge_from(p)
        return merged

    def percentiles(self, name: str, qs: Sequence[float] = (0.5, 0.99, 0.999)
                    ) -> list[Optional[float]]:
        merged = self.merged_histogram(name)
        if merged is None:
            return [None] * len(qs)
        return merged.percentiles(qs)

    # -- lifecycle -----------------------------------------------------------

    def snapshot(self) -> dict:
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._histograms.values())
            sources = dict(self._sources)
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}, "sources": {}}
        for c in counters:
            out["counters"].setdefault(c.name, []).append(c.snapshot())
        for g in gauges:
            out["gauges"].setdefault(g.name, []).append(g.snapshot())
        for h in hists:
            out["histograms"].setdefault(h.name, []).append(h.snapshot())
        for name, (snap, _reset) in sources.items():
            out["sources"][name] = snap()
        out["self"] = {"seconds": self.meter.seconds, "events": self.meter.events}
        return out

    def reset(self) -> None:
        with self._lock:
            metrics = (list(self._counters.values()) + list(self._gauges.values())
                       + list(self._histograms.values()))
            sources = dict(self._sources)
        for m in metrics:
            m.reset()
        for _name, (_snap, reset) in sources.items():
            if reset is not None:
                reset()
        self.meter.reset()
