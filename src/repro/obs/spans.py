"""Per-prefetch lifecycle spans (ISSUE 6 tentpole).

Every prefetched oid gets ONE :class:`PrefetchSpan` per residency
generation, threaded through its whole life:

  predicted  — a predictor emitted the oid (origin = predictor + hint
               context);
  dispatched — ``ObjectStore.prefetch_batch`` grouped it into a batch for
               its owning Data Service (batch id assigned here);
  claimed    — ``DataService.claim_prefetch_batch`` won the dedupe (or the
               span terminates ``suppressed``: already resident/in flight);
  queued/loaded — a batch lane picked the oid into a chunk (``queued_t``),
               acquired a disk slot (``load_start_t``: slot wait ends) and
               landed it (``load_done_t``: service time ends);
  terminal   — exactly one of:
               * ``hit``      — first demand access found it resident
                 (stall 0, ``hidden_s`` = the disk load removed from the
                 app's critical path);
               * ``partial``  — first demand access caught the load in
                 flight (``stall_s`` = the remainder the app waited);
               * ``evicted``  — evicted before any demand use;
               * ``suppressed`` — deduped before any load was submitted;
               * ``dropped``  — cancelled on drain / reset / error.

Demand *misses* get the same span shape (kind ``demand``, terminal
``miss``) so stall attribution is symmetric: the timeline shows exactly
where every second of disk wait went, hidden or not.

The tracer is clock-agnostic: the live store records wall timestamps
(``time.perf_counter``), the replay engine passes explicit virtual times —
the exported span fields are identical, which is what makes wall and
virtual timelines comparable side by side.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence

from .metrics import Meter

#: terminal outcomes a span can reach (exactly one each)
TERMINAL_OUTCOMES = ("hit", "partial", "miss", "evicted", "suppressed", "dropped")


class SpanError(AssertionError):
    """A span lifecycle invariant was violated."""


@dataclass
class PrefetchSpan:
    oid: int
    kind: str = "prefetch"  # "prefetch" | "demand"
    origin: str = ""  # predictor name + hint/method context
    service: int = -1
    session: str = ""
    batch_id: int = -1
    lane: int = -1
    predicted_t: Optional[float] = None
    dispatched_t: Optional[float] = None
    claimed_t: Optional[float] = None
    queued_t: Optional[float] = None
    load_start_t: Optional[float] = None
    load_done_t: Optional[float] = None
    outcome: str = ""  # "" while active; one of TERMINAL_OUTCOMES when done
    outcome_t: Optional[float] = None
    hidden_s: float = 0.0
    stall_s: float = 0.0
    re_predicted: int = 0  # later predictions of the same live span

    @property
    def terminal(self) -> bool:
        return bool(self.outcome)

    @property
    def slot_wait_s(self) -> Optional[float]:
        if self.queued_t is None or self.load_start_t is None:
            return None
        return self.load_start_t - self.queued_t

    @property
    def service_s(self) -> Optional[float]:
        if self.load_start_t is None or self.load_done_t is None:
            return None
        return self.load_done_t - self.load_start_t

    def fields_set(self) -> tuple[str, ...]:
        """Names of the populated lifecycle fields — the wall-vs-virtual
        parity check compares these, not the (clock-dependent) values."""
        keys = ("predicted_t", "dispatched_t", "claimed_t", "queued_t",
                "load_start_t", "load_done_t", "outcome_t")
        return tuple(k for k in keys if getattr(self, k) is not None)


class Tracer:
    """Collects spans from either clock.  All mutation goes through the
    lifecycle methods below; ``t=None`` means "now" on the tracer's clock
    (the live store's wall clock), explicit ``t`` is the virtual replay's
    spelling.  Thread-safe; the internal lock is a leaf (never acquires any
    store lock), so calls are safe under a Data Service's cache lock."""

    def __init__(self, clock=None, meter: Optional[Meter] = None,
                 session: str = ""):
        self.clock = clock or time.perf_counter
        self.meter = meter
        self.session = session
        self._lock = threading.Lock()
        self._active: dict[int, PrefetchSpan] = {}
        self._done: list[PrefetchSpan] = []
        self._batch_ids = 0
        # point-in-time markers outside any span's lifecycle (failover,
        # service crash/down, demand steal, straggler flags): rendered as
        # Perfetto instant events on the service's track
        self._instants: list[dict] = []
        self.events = 0

    # -- internals -----------------------------------------------------------

    def now(self) -> float:
        return self.clock()

    def _charge(self, t0: float) -> None:
        m = self.meter
        if m is not None:
            m.events += 1
            m.seconds += time.perf_counter() - t0

    def _finish(self, span: PrefetchSpan, outcome: str, t: float) -> None:
        """Move a span to its single terminal state (callers hold the
        lock)."""
        if span.terminal:
            raise SpanError(
                f"span oid={span.oid} already terminal ({span.outcome}); "
                f"second outcome {outcome}"
            )
        span.outcome = outcome
        span.outcome_t = t
        self._active.pop(span.oid, None)
        self._done.append(span)

    # -- lifecycle recording -------------------------------------------------

    def predicted(self, oids: Iterable[int], origin: str = "",
                  t: Optional[float] = None, session: str = "") -> None:
        t0 = time.perf_counter()
        ts = self.clock() if t is None else t
        who = session or self.session
        with self._lock:
            self.events += 1
            for oid in oids:
                span = self._active.get(oid)
                if span is not None:
                    span.re_predicted += 1
                    continue
                self._active[oid] = PrefetchSpan(
                    oid=oid, origin=origin, predicted_t=ts, session=who
                )
        self._charge(t0)

    def new_batch(self) -> int:
        with self._lock:
            self._batch_ids += 1
            return self._batch_ids

    def dispatched(self, oids: Iterable[int], service: int, batch_id: int = -1,
                   t: Optional[float] = None, session: str = "") -> None:
        t0 = time.perf_counter()
        ts = self.clock() if t is None else t
        who = session or self.session
        with self._lock:
            self.events += 1
            for oid in oids:
                span = self._active.get(oid)
                if span is None:
                    # dispatch without a recorded prediction (e.g. the
                    # legacy generated closure): open the span here
                    span = PrefetchSpan(oid=oid, predicted_t=ts,
                                        session=who)
                    self._active[oid] = span
                if span.dispatched_t is None:
                    span.dispatched_t = ts
                    span.service = service
                    span.batch_id = batch_id
        self._charge(t0)

    def claimed(self, oids: Iterable[int], service: int,
                t: Optional[float] = None) -> None:
        t0 = time.perf_counter()
        ts = self.clock() if t is None else t
        with self._lock:
            self.events += 1
            for oid in oids:
                span = self._active.get(oid)
                if span is not None and span.claimed_t is None:
                    span.claimed_t = ts
                    span.service = service
        self._charge(t0)

    def suppressed(self, oids: Iterable[int], service: int,
                   t: Optional[float] = None) -> None:
        """Deduped before submission (already resident / in flight /
        duplicate).  Terminal only for spans that never got past dispatch;
        a span whose load is underway just counts a re-prediction."""
        t0 = time.perf_counter()
        ts = self.clock() if t is None else t
        with self._lock:
            self.events += 1
            for oid in oids:
                span = self._active.get(oid)
                if span is None:
                    continue
                if span.claimed_t is None and span.load_done_t is None:
                    span.service = service if span.service < 0 else span.service
                    self._finish(span, "suppressed", ts)
                else:
                    span.re_predicted += 1
        self._charge(t0)

    def loaded(self, oids: Iterable[int], service: int, lane: int,
               queued_t: float, start_t: float, done_t: float,
               session: str = "") -> None:
        """A batch lane landed a chunk: slot wait = ``start - queued``,
        service time = ``done - start`` (chunk-granular on the wall clock:
        the chunk's sequential loads share one slot hold)."""
        t0 = time.perf_counter()
        who = session or self.session
        with self._lock:
            self.events += 1
            for oid in oids:
                span = self._active.get(oid)
                if span is None:
                    span = PrefetchSpan(oid=oid, predicted_t=queued_t,
                                        dispatched_t=queued_t, service=service,
                                        session=who)
                    self._active[oid] = span
                span.lane = lane
                span.service = service
                if span.queued_t is None:
                    span.queued_t = queued_t
                span.load_start_t = start_t
                span.load_done_t = done_t
        self._charge(t0)

    def demand(self, oid: int, service: int, needed_t: float, stall_s: float,
               full_load: bool, disk_load_s: float,
               t: Optional[float] = None, session: str = "") -> None:
        """A demand access touched ``oid``.  If a prefetch span is live,
        this is its terminal ``hit`` (resident: full disk load hidden) or
        ``partial`` (in flight: the app waited out ``stall_s``); otherwise
        a full miss opens-and-closes a symmetric demand span.  Plain cache
        hits with no live span record nothing (bounded memory)."""
        t0 = time.perf_counter()
        end_t = (needed_t + stall_s) if t is None else t
        with self._lock:
            self.events += 1
            span = self._active.get(oid)
            if span is not None and span.kind == "prefetch":
                span.stall_s = stall_s
                if full_load:
                    # the prefetch never landed in time and the demand path
                    # re-loaded it itself: nothing was hidden
                    span.hidden_s = 0.0
                    self._finish(span, "miss", end_t)
                elif stall_s > 0.0 and span.load_done_t is not None and \
                        span.load_done_t > needed_t:
                    span.hidden_s = max(0.0, disk_load_s - stall_s)
                    self._finish(span, "partial", end_t)
                else:
                    span.hidden_s = disk_load_s
                    span.stall_s = 0.0
                    self._finish(span, "hit", end_t)
            elif full_load:
                miss = PrefetchSpan(
                    oid=oid, kind="demand", service=service,
                    session=session or self.session,
                    predicted_t=needed_t, queued_t=needed_t,
                    load_start_t=needed_t, load_done_t=end_t,
                    stall_s=stall_s,
                )
                miss.outcome = "miss"
                miss.outcome_t = end_t
                self._done.append(miss)
        self._charge(t0)

    def instant(self, name: str, service: int = -1,
                t: Optional[float] = None, **args) -> None:
        """Record a point-in-time marker (retry/failover/crash/steal
        instants — events that are not a phase of any one span's life)."""
        t0 = time.perf_counter()
        ts = self.clock() if t is None else t
        with self._lock:
            self.events += 1
            self._instants.append(
                {"name": name, "service": service, "t": ts, "args": args}
            )
        self._charge(t0)

    def evicted(self, oid: int, t: Optional[float] = None) -> None:
        t0 = time.perf_counter()
        ts = self.clock() if t is None else t
        with self._lock:
            self.events += 1
            span = self._active.get(oid)
            if span is not None:
                self._finish(span, "evicted", ts)
        self._charge(t0)

    def dropped(self, oids: Iterable[int], reason: str = "error",
                t: Optional[float] = None) -> None:
        t0 = time.perf_counter()
        ts = self.clock() if t is None else t
        with self._lock:
            self.events += 1
            for oid in oids:
                span = self._active.get(oid)
                if span is not None:
                    span.origin = span.origin or reason
                    self._finish(span, "dropped", ts)
        self._charge(t0)

    def drop_active(self, reason: str = "drained",
                    t: Optional[float] = None) -> int:
        """Terminate every still-active span (hard drain, store reset, end
        of run) so the lifecycle invariant — exactly one terminal state per
        dispatched span — holds even through cancellation."""
        ts = self.clock() if t is None else t
        with self._lock:
            self.events += 1
            live = list(self._active.values())
            for span in live:
                self._finish(span, "dropped", ts)
        return len(live)

    # -- read side -----------------------------------------------------------

    def spans(self) -> list[PrefetchSpan]:
        with self._lock:
            return list(self._done) + list(self._active.values())

    def instants(self) -> list[dict]:
        with self._lock:
            return list(self._instants)

    def active_count(self) -> int:
        with self._lock:
            return len(self._active)

    def counts(self) -> dict:
        with self._lock:
            out: dict = {"active": len(self._active), "total": len(self._done) + len(self._active)}
            for span in self._done:
                key = f"outcome_{span.outcome}"
                out[key] = out.get(key, 0) + 1
            return out

    def reset(self) -> None:
        with self._lock:
            self._active.clear()
            self._done.clear()
            self._batch_ids = 0
            self._instants.clear()
            self.events = 0


def check_span_invariants(spans: Sequence[PrefetchSpan]) -> list[str]:
    """Lifecycle invariants the test suite (and CI) hold every run to.
    Returns human-readable violations (empty = pass):

      * every span is terminal with exactly one outcome from the vocabulary;
      * every *dispatched* prefetch span that loaded has the full phase
        chain (predicted <= dispatched <= claimed <= queued <= start <=
        done), monotone;
      * hits/partials carry non-negative hidden/stall attribution.
    """
    problems: list[str] = []
    for span in spans:
        label = f"oid={span.oid}/{span.kind}"
        if not span.terminal:
            problems.append(f"{label}: no terminal outcome")
            continue
        if span.outcome not in TERMINAL_OUTCOMES:
            problems.append(f"{label}: unknown outcome {span.outcome!r}")
        chain = [span.predicted_t, span.dispatched_t, span.claimed_t,
                 span.queued_t, span.load_start_t, span.load_done_t,
                 span.outcome_t]
        present = [t for t in chain if t is not None]
        if any(b < a - 1e-9 for a, b in zip(present, present[1:])):
            problems.append(f"{label}: non-monotone phase timestamps {present}")
        if span.kind == "prefetch" and span.load_done_t is not None \
                and span.outcome in ("hit", "partial") and span.claimed_t is None:
            problems.append(f"{label}: loaded+used span was never claimed")
        if span.hidden_s < 0 or span.stall_s < 0:
            problems.append(f"{label}: negative attribution "
                            f"hidden={span.hidden_s} stall={span.stall_s}")
    return problems
