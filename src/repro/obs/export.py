"""Chrome-trace / Perfetto JSON export of prefetch lifecycle spans.

The exported object follows the Chrome Trace Event format (the JSON flavour
Perfetto's UI at https://ui.perfetto.dev opens directly):

  * one **process track per Data Service** (pid = service id), one thread
    track per batch lane (tid), plus a dedicated demand-path track;
  * each span renders as a chain of ``"X"`` (complete) slices — one per
    lifecycle phase: ``predicted`` (prediction → dispatch), ``dispatch``
    (dispatch → claim), ``lane_wait`` (claim → chunk pickup), ``slot_wait``
    (chunk pickup → disk slot acquired) and ``disk`` (slot service time) —
    so a prefetched oid shows >= 4 phases end to end;
  * the terminal outcome is an ``"i"`` (instant) event carrying the
    hidden/stalled attribution in ``args``;
  * every prefetch span that reached its demand use also emits a **flow
    arrow** (``"s"`` → ``"t"`` → ``"f"``, one shared numeric ``id`` per
    span): prediction → load landing → demand hit, so Perfetto draws the
    causal chain across tracks instead of leaving three disjoint slices;
  * ``"C"`` (counter) tracks derive disk-slot occupancy per service and a
    demand-queue depth from the spans themselves, so PR 5's demand-priority
    handoffs are visible without extra hooks.

Wall-clock runs pass ``perf_counter`` timestamps (normalized so the trace
starts at ts=0); virtual-clock replays pass virtual seconds, which map 1:1
to trace microseconds — the same exporter serves both stacks.
"""

from __future__ import annotations

import json
from typing import Iterable, Optional, Sequence

from .spans import PrefetchSpan

#: ordered (phase name, start attr, end attr) — the span's renderable slices
PHASE_EDGES = (
    ("predicted", "predicted_t", "dispatched_t"),
    ("dispatch", "dispatched_t", "claimed_t"),
    ("lane_wait", "claimed_t", "queued_t"),
    ("slot_wait", "queued_t", "load_start_t"),
    ("disk", "load_start_t", "load_done_t"),
)

_DEMAND_TID = 9999  # dedicated per-service demand-path track


def _us(t: float, t0: float) -> float:
    return max(0.0, (t - t0) * 1e6)


def chrome_trace(spans: Sequence[PrefetchSpan], *, clock: str = "wall",
                 counters: bool = True,
                 instants: Sequence[dict] = (),
                 process_names: Optional[dict] = None) -> dict:
    """Serialize spans to a Chrome-trace JSON object.

    ``clock`` is recorded in trace metadata ("wall" | "virtual"); virtual
    traces already start near 0, wall traces are normalized to the earliest
    timestamp so Perfetto doesn't render hours of empty lead-in.

    ``instants`` are ``Tracer.instants()`` markers (failover / crash /
    demand-steal) rendered as process-scoped instant events on their
    service's track.  ``process_names`` overrides per-pid track labels —
    how non-store producers (e.g. the weight streamer at its own pid)
    share one timeline with the Data Services.
    """
    ts_all = [t for s in spans
              for t in (s.predicted_t, s.load_done_t, s.outcome_t)
              if t is not None]
    ts_all.extend(i["t"] for i in instants)
    t0 = min(ts_all) if ts_all else 0.0
    if clock == "virtual":
        t0 = 0.0

    events: list[dict] = []
    services: set[int] = set()
    lanes: set[tuple[int, int]] = set()

    for flow_id, span in enumerate(spans):
        pid = max(span.service, 0)
        services.add(pid)
        tid = _DEMAND_TID if span.kind == "demand" else max(span.lane, 0)
        lanes.add((pid, tid))
        name = f"oid {span.oid}"
        args = {
            "oid": span.oid,
            "kind": span.kind,
            "origin": span.origin,
            "batch_id": span.batch_id,
            "outcome": span.outcome,
            "session": span.session,
        }
        for phase, a, b in PHASE_EDGES:
            ta, tb = getattr(span, a), getattr(span, b)
            if ta is None or tb is None:
                continue
            events.append({
                "name": f"{phase}:{name}" if phase != "disk" else name,
                "cat": f"{span.kind},{phase}",
                "ph": "X",
                "ts": _us(ta, t0),
                "dur": max(0.0, (tb - ta) * 1e6),
                "pid": pid,
                "tid": tid,
                "args": args,
            })
        if span.outcome and span.outcome_t is not None:
            events.append({
                "name": f"{span.outcome}:{name}",
                "cat": f"{span.kind},outcome",
                "ph": "i",
                "s": "t",
                "ts": _us(span.outcome_t, t0),
                "pid": pid,
                "tid": tid,
                "args": {**args, "hidden_s": span.hidden_s,
                         "stall_s": span.stall_s,
                         "re_predicted": span.re_predicted},
            })
        # flow arrow prediction -> load landing -> demand use: only spans
        # whose prefetch actually met a demand access get one (hit/partial);
        # the three events share this span's numeric id, which is what
        # Perfetto keys the arrow rendering on
        if (span.kind == "prefetch" and span.outcome in ("hit", "partial")
                and span.predicted_t is not None and span.outcome_t is not None):
            flow = {"name": name, "cat": "prefetch,flow", "id": flow_id,
                    "pid": pid, "tid": tid}
            events.append({**flow, "ph": "s",
                           "ts": _us(span.predicted_t, t0)})
            if span.load_done_t is not None:
                events.append({**flow, "ph": "t",
                               "ts": _us(span.load_done_t, t0)})
            events.append({**flow, "ph": "f", "bp": "e",
                           "ts": _us(span.outcome_t, t0)})

    for marker in instants:
        pid = max(int(marker.get("service", -1)), 0)
        services.add(pid)
        events.append({
            "name": marker["name"],
            "cat": "fault",
            "ph": "i",
            "s": "p",  # process-scoped: the whole service track flags it
            "ts": _us(marker["t"], t0),
            "pid": pid,
            "tid": 0,
            "args": dict(marker.get("args", {})),
        })

    if counters:
        events.extend(_occupancy_counters(spans, t0))

    # metadata: readable process/thread names in the Perfetto track list
    names = process_names or {}
    for pid in sorted(services):
        events.append({"name": "process_name", "ph": "M", "ts": 0.0,
                       "pid": pid, "tid": 0,
                       "args": {"name": names.get(pid, f"data-service {pid}")}})
    for pid, tid in sorted(lanes):
        label = "demand path" if tid == _DEMAND_TID else f"lane {tid}"
        events.append({"name": "thread_name", "ph": "M", "ts": 0.0,
                       "pid": pid, "tid": tid, "args": {"name": label}})

    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"clock": clock, "spans": len(spans)},
    }


def _occupancy_counters(spans: Sequence[PrefetchSpan], t0: float) -> list[dict]:
    """Derive disk-slot occupancy (per service) and demand-queue depth
    counter tracks from span edges: +1 when a load enters the disk, -1 when
    it lands; demand depth spans the stall window of each demand access."""
    deltas: dict[tuple[int, str], list[tuple[float, int]]] = {}

    def edge(pid: int, track: str, t: float, d: int) -> None:
        deltas.setdefault((pid, track), []).append((t, d))

    for span in spans:
        pid = max(span.service, 0)
        if span.load_start_t is not None and span.load_done_t is not None:
            edge(pid, "disk_busy", span.load_start_t, +1)
            edge(pid, "disk_busy", span.load_done_t, -1)
        if span.kind == "demand" or span.stall_s > 0:
            start = span.predicted_t if span.kind == "demand" else span.outcome_t
            if start is not None and span.outcome_t is not None:
                begin = min(start, span.outcome_t)
                end = max(begin, span.outcome_t) if span.kind == "demand" \
                    else begin + span.stall_s
                edge(pid, "demand_queue", begin, +1)
                edge(pid, "demand_queue", end, -1)

    events: list[dict] = []
    for (pid, track), edges in sorted(deltas.items()):
        edges.sort(key=lambda e: e[0])
        level = 0
        for t, d in edges:
            level += d
            events.append({
                "name": track, "ph": "C", "ts": _us(t, t0),
                "pid": pid, "tid": 0, "args": {track: max(0, level)},
            })
    return events


def validate_chrome_trace(obj) -> list[str]:
    """Schema check for an exported trace object.  Returns human-readable
    problems (empty list = valid): traceEvents must be a list of events each
    carrying name/ph/ts/pid/tid, ts >= 0, "X" events a non-negative dur,
    and the whole object must be JSON-serializable."""
    problems: list[str] = []
    if not isinstance(obj, dict):
        return ["trace is not a JSON object"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    try:
        json.dumps(obj)
    except (TypeError, ValueError) as exc:
        problems.append(f"not JSON-serializable: {exc}")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"event {i}: missing {key!r}")
        ts = ev.get("ts")
        if isinstance(ts, (int, float)) and ts < 0:
            problems.append(f"event {i}: negative ts {ts}")
        if ev.get("ph") == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X event with bad dur {dur!r}")
        if ev.get("ph") in ("s", "t", "f") and not isinstance(ev.get("id"), int):
            problems.append(
                f"event {i}: flow event ({ev.get('ph')}) without a numeric id")
        if len(problems) > 20:
            problems.append("... (truncated)")
            break
    return problems


def validate_flow_pairing(obj) -> list[str]:
    """Flow-arrow consistency: every flow id must open with an ``"s"``,
    close with at most one ``"f"``, and run monotone in time — a dangling
    ``"t"``/``"f"`` renders as an arrow from nowhere in Perfetto."""
    problems: list[str] = []
    flows: dict[int, dict[str, list[float]]] = {}
    for ev in obj.get("traceEvents", []):
        ph = ev.get("ph")
        if ph not in ("s", "t", "f"):
            continue
        by_ph = flows.setdefault(ev.get("id"), {"s": [], "t": [], "f": []})
        by_ph[ph].append(ev.get("ts", 0.0))
    for fid, by_ph in sorted(flows.items(), key=lambda kv: (kv[0] is None, kv[0])):
        if len(by_ph["s"]) != 1:
            problems.append(f"flow {fid}: {len(by_ph['s'])} start events (want 1)")
            continue
        if len(by_ph["f"]) > 1:
            problems.append(f"flow {fid}: {len(by_ph['f'])} finish events (want <= 1)")
        chain = by_ph["s"] + sorted(by_ph["t"]) + by_ph["f"]
        if any(b < a for a, b in zip(chain, chain[1:])):
            problems.append(f"flow {fid}: non-monotone timestamps {chain}")
    return problems


def full_lifecycle_phase_counts(obj) -> dict[int, int]:
    """oid -> number of distinct lifecycle phases present in the trace —
    the acceptance check that every prefetched oid renders >= 4 phases."""
    phases: dict[int, set] = {}
    for ev in obj.get("traceEvents", []):
        cat = ev.get("cat", "")
        if ev.get("ph") != "X" or not cat.startswith("prefetch"):
            continue
        oid = ev.get("args", {}).get("oid")
        if oid is None:
            continue
        phases.setdefault(oid, set()).add(cat.split(",", 1)[-1])
    return {oid: len(ps) for oid, ps in phases.items()}


def write_chrome_trace(path, spans: Sequence[PrefetchSpan], *,
                       clock: str = "wall", counters: bool = True,
                       instants: Sequence[dict] = (),
                       process_names: Optional[dict] = None) -> dict:
    """Export + validate + write in one step; raises on schema violations
    so a benchmark can't silently publish a broken timeline."""
    trace = chrome_trace(spans, clock=clock, counters=counters,
                         instants=instants, process_names=process_names)
    problems = validate_chrome_trace(trace) + validate_flow_pairing(trace)
    if problems:
        raise ValueError(f"invalid chrome trace: {problems[:5]}")
    with open(path, "w") as fh:
        json.dump(trace, fh)
    return trace
