"""Unified observability layer (DESIGN.md section 3.7).

One measurement substrate shared by the live ``ObjectStore`` (wall clock)
and the ``VirtualReplay`` engine (virtual clock):

  * ``metrics``  — a low-overhead :class:`Registry` of named counters,
    gauges and log-bucketed :class:`Histogram`\\ s with per-service /
    per-session labels, absorbing the repo's previously disjoint metric
    surfaces (``StoreMetrics``, ``StreamMetrics``, ``Overhead``) behind one
    ``snapshot()`` / ``reset()`` API;
  * ``spans``    — per-prefetch lifecycle records (:class:`PrefetchSpan`)
    threaded from prediction through dispatch, claim, disk queue and load
    to exactly one terminal outcome, collected by a :class:`Tracer` that
    works on either clock;
  * ``export``   — Chrome-trace / Perfetto JSON serialization of spans plus
    derived disk/demand-queue occupancy, so a benchmark run renders as an
    inspectable timeline.

Instrumentation cost is itself metered (:class:`Meter`) and charged to the
prediction ``Overhead`` ledger, so CAPre's zero-overhead claim stays
falsifiable even with the instruments attached.
"""

from .metrics import Counter, Gauge, Histogram, Meter, Registry
from .spans import PrefetchSpan, SpanError, Tracer, check_span_invariants
from .export import (
    chrome_trace,
    full_lifecycle_phase_counts,
    validate_chrome_trace,
    validate_flow_pairing,
    write_chrome_trace,
)

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Observability:
    """The context a host (store, streamer, replay engine) is instrumented
    with: a metrics registry, optionally a span tracer, and one shared
    :class:`Meter` accounting the instrumentation's own cost."""

    registry: Registry = field(default_factory=Registry)
    tracer: Optional[Tracer] = None
    tracing: bool = False

    def __post_init__(self) -> None:
        if self.tracing and self.tracer is None:
            self.tracer = Tracer(meter=self.registry.meter)
        elif self.tracer is not None and self.tracer.meter is None:
            self.tracer.meter = self.registry.meter

    @property
    def meter(self) -> Meter:
        return self.registry.meter

    def snapshot(self) -> dict:
        out = self.registry.snapshot()
        if self.tracer is not None:
            out["spans"] = self.tracer.counts()
        return out

    def reset(self) -> None:
        self.registry.reset()
        if self.tracer is not None:
            self.tracer.reset()

    def charge(self, overhead) -> None:
        """Add this context's metered instrumentation cost to a prediction
        ``Overhead`` ledger (``obs_seconds`` / ``obs_events``)."""
        overhead.obs_seconds += self.meter.seconds
        overhead.obs_events += self.meter.events


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Meter",
    "Observability",
    "PrefetchSpan",
    "Registry",
    "SpanError",
    "Tracer",
    "check_span_invariants",
    "chrome_trace",
    "full_lifecycle_phase_counts",
    "validate_chrome_trace",
    "validate_flow_pairing",
    "write_chrome_trace",
]
