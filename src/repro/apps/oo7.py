"""The OO7 benchmark (Carey, DeWitt, Naughton, SIGMOD'93) — paper section
7.2.1, Figure 9.

Data model: a Module owns a tree of ComplexAssemblies; the leaves are
BaseAssemblies referencing CompositeParts; each CompositePart has a
documentation Document and a graph of AtomicParts connected by Connections.

The assembly hierarchy is polymorphic (Assembly -> ComplexAssembly |
BaseAssembly with an overridden ``traverse``), which exercises CAPre's
overridden-method exclusion: the static analysis cannot inline
``sub.traverse()``, so each assembly level schedules its own prefetch at
runtime — exactly why the paper's OO7 gains (26-30%) are smaller than
Wordcount's (>50%).

Traversals implemented (section 7.2.1):
  * t1  — full traversal: DFS over the assembly hierarchy, then the atomic
          part graph of every referenced composite part (data access speed);
  * t2b — t1 plus an update of every atomic part (update speed: the write
          cost dominates and prefetching cannot help).
"""

from __future__ import annotations

import random

from repro.core.lang import (
    Application,
    Call,
    ClassDef,
    Compute,
    Const,
    COLLECTION,
    ExprStmt,
    FieldSpec,
    ForEach,
    ForEachLocal,
    Get,
    If,
    Let,
    MethodDef,
    Return,
    SetField,
    This,
    Var,
    fields_of,
)


def build_oo7_app() -> Application:
    module = ClassDef(
        "Module",
        fields_of(
            FieldSpec("designRoot", target="ComplexAssembly"),
            FieldSpec("manual", target="Manual"),
            FieldSpec("id"),
        ),
    )
    manual = ClassDef("Manual", fields_of(FieldSpec("text")))

    assembly = ClassDef("Assembly", fields_of(FieldSpec("id")))
    assembly.add_method(MethodDef("traverse", params=(), ret_type=None, body=[Return(Const(0))]))

    complex_asm = ClassDef(
        "ComplexAssembly",
        fields_of(FieldSpec("subAssemblies", target="Assembly", card=COLLECTION)),
        supertype="Assembly",
    )
    # traverse(): for (Assembly sub : subAssemblies) sub.traverse();
    complex_asm.add_method(
        MethodDef(
            "traverse",
            params=(),
            body=[
                Let("acc", Const(0)),
                ForEach(
                    "sub",
                    This(),
                    "subAssemblies",
                    [Let("acc", Compute(lambda a, b: a + b, (Var("acc"), Call(Var("sub"), "traverse")), "add"))],
                ),
                Return(Var("acc")),
            ],
        )
    )

    base_asm = ClassDef(
        "BaseAssembly",
        fields_of(FieldSpec("components", target="CompositePart", card=COLLECTION)),
        supertype="Assembly",
    )
    base_asm.add_method(
        MethodDef(
            "traverse",
            params=(),
            body=[
                Let("acc", Const(0)),
                ForEach(
                    "cp",
                    This(),
                    "components",
                    [Let("acc", Compute(lambda a, b: a + b, (Var("acc"), Call(Var("cp"), "traverseCP")), "add"))],
                ),
                Return(Var("acc")),
            ],
        )
    )

    composite = ClassDef(
        "CompositePart",
        fields_of(
            FieldSpec("rootPart", target="AtomicPart"),
            FieldSpec("documentation", target="Document"),
            FieldSpec("parts", target="AtomicPart", card=COLLECTION),
            FieldSpec("buildDate"),
        ),
    )
    # traverseCP(): touch the documentation, then DFS over the atomic-part
    # graph starting from rootPart, following connections (single assocs).
    composite.add_method(
        MethodDef(
            "traverseCP",
            params=(),
            body=[
                ExprStmt(Get(Get(This(), "documentation"), "title")),
                Let("visited", Compute(lambda: set(), (), "newSet")),
                Return(Call(Get(This(), "rootPart"), "visitAtomic", (Var("visited"),))),
            ],
        )
    )

    document = ClassDef("Document", fields_of(FieldSpec("title"), FieldSpec("text")))

    atomic = ClassDef(
        "AtomicPart",
        fields_of(
            FieldSpec("to", target="Connection", card=COLLECTION),
            FieldSpec("partOf", target="CompositePart"),
            FieldSpec("x"),
            FieldSpec("y"),
            FieldSpec("docId"),
        ),
    )
    # visitAtomic(visited): DFS over connections; recursion is cut by the
    # static analysis (back edge) but each call re-schedules its own prefetch.
    atomic.add_method(
        MethodDef(
            "visitAtomic",
            params=(("visited", None),),
            body=[
                If(
                    Compute(lambda s, me: id_in(s, me), (Var("visited"), This()), "seen"),
                    then=[Return(Const(0))],
                ),
                ExprStmt(Compute(lambda s, me: s.add(me), (Var("visited"), This()), "mark")),
                Let("acc", Get(This(), "x")),
                ForEach(
                    "conn",
                    This(),
                    "to",
                    [
                        Let("nxt", Get(Var("conn"), "toPart")),
                        Let(
                            "acc",
                            Compute(
                                lambda a, b: a + b,
                                (Var("acc"), Call(Var("nxt"), "visitAtomic", (Var("visited"),))),
                                "add",
                            ),
                        ),
                    ],
                ),
                Return(Var("acc")),
            ],
        )
    )
    # t2b's per-part update: swap x and y and bump the build date.
    atomic.add_method(
        MethodDef(
            "updatePart",
            params=(),
            body=[
                Let("ox", Get(This(), "x")),
                SetField(This(), "x", Get(This(), "y")),
                SetField(This(), "y", Var("ox")),
            ],
        )
    )

    connection = ClassDef(
        "Connection",
        fields_of(FieldSpec("toPart", target="AtomicPart"), FieldSpec("length"), FieldSpec("ctype")),
    )

    bench = ClassDef("OO7Bench", fields_of(FieldSpec("module", target="Module")))
    # t1: full read traversal from the module.
    bench.add_method(
        MethodDef(
            "t1",
            params=(),
            body=[Return(Call(Get(Get(This(), "module"), "designRoot"), "traverse"))],
        )
    )
    # t2b: traverse and update every atomic part of every composite part.
    bench.add_method(
        MethodDef(
            "t2b",
            params=(),
            body=[
                ExprStmt(Call(Get(Get(This(), "module"), "designRoot"), "updateAll")),
            ],
        )
    )
    complex_asm.add_method(
        MethodDef(
            "updateAll",
            params=(),
            body=[ForEach("sub", This(), "subAssemblies", [ExprStmt(Call(Var("sub"), "updateAll"))])],
        )
    )
    base_asm.add_method(
        MethodDef(
            "updateAll",
            params=(),
            body=[
                ForEach(
                    "cp",
                    This(),
                    "components",
                    [ForEach("p", Var("cp"), "parts", [ExprStmt(Call(Var("p"), "updatePart"))])],
                )
            ],
        )
    )
    assembly.add_method(MethodDef("updateAll", params=(), body=[Return(None)]))
    # re-add so the override map sees traverse/updateAll on all three
    for c in (assembly, complex_asm, base_asm):
        for m in c.methods.values():
            m.owner = c.name

    return Application(
        name="oo7",
        classes={
            c.name: c
            for c in [module, manual, assembly, complex_asm, base_asm, composite, document, atomic, connection, bench]
        },
    )


def id_in(s: set, ref) -> bool:
    return ref in s


# ---------------------------------------------------------------------------
# Database generator (sizes follow the OO7 small/medium spirit, scaled so the
# wall-clock simulation stays in seconds)
# ---------------------------------------------------------------------------

SIZES = {
    # levels in the assembly tree, fan-out, composite parts per base
    # assembly, atomic parts per composite part
    "small": dict(levels=4, fanout=3, comps_per_base=4, atoms_per_comp=12),
    "medium": dict(levels=5, fanout=3, comps_per_base=5, atoms_per_comp=16),
}


def populate_oo7(store, size: str = "small", seed: int = 7) -> int:
    cfg = SIZES[size]
    rng = random.Random(seed)

    def make_composite(idx: int) -> int:
        # a composite part's traversal closure (parts + connections + doc)
        # is one locality group — T1/T6 walk it in full, so a locality-aware
        # placement keeps the whole subtree on one Data Service
        grp = f"cp{idx}"
        doc = store.put("Document", {"title": f"doc{idx}", "text": "x" * 16}, group=grp)
        n = cfg["atoms_per_comp"]
        atoms = [
            store.put("AtomicPart", {"x": float(i), "y": float(i) * 2, "docId": idx, "to": [], "partOf": None},
                      group=grp)
            for i in range(n)
        ]
        # connect the parts in a ring plus a few random chords (the OO7
        # atomic graph has out-degree 3)
        for i, a in enumerate(atoms):
            targets = {atoms[(i + 1) % n]}
            while len(targets) < 3:
                targets.add(atoms[rng.randrange(n)])
            conns = [
                store.put("Connection", {"toPart": t, "length": rng.random(), "ctype": "c"},
                          group=grp)
                for t in targets
            ]
            store.peek(a).fields["to"] = conns
        cp = store.put(
            "CompositePart",
            {"rootPart": atoms[0], "documentation": doc, "parts": atoms, "buildDate": idx},
            group=grp,
        )
        for a in atoms:
            store.peek(a).fields["partOf"] = cp
        return cp

    comp_counter = [0]

    def make_assembly(level: int) -> int:
        if level == cfg["levels"]:
            comps = []
            for _ in range(cfg["comps_per_base"]):
                comps.append(make_composite(comp_counter[0]))
                comp_counter[0] += 1
            return store.put("BaseAssembly", {"components": comps, "id": level})
        subs = [make_assembly(level + 1) for _ in range(cfg["fanout"])]
        return store.put("ComplexAssembly", {"subAssemblies": subs, "id": level})

    root_asm = make_assembly(1)
    man = store.put("Manual", {"text": "m" * 32})
    module = store.put("Module", {"designRoot": root_asm, "manual": man, "id": 0})
    return store.put("OO7Bench", {"module": module})
