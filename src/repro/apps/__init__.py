"""Benchmark and example applications, written once in the ``core.lang`` AST
and used both by the static analysis and by the POS interpreter."""
