"""K-Means benchmark (paper section 7.2.3, Figure 13).

Data model: VectorCollection ->> Vector, nothing else — crucially there are
**no single associations**, so ROP has literally nothing to prefetch
regardless of its fetch depth (the paper's Figure 14), while CAPre predicts
the vector collections and prefetches them in parallel.  The algorithm is
iterative; after the first pass the store is warm, so the paper's observed
9-15% improvement is structurally what this model produces.
"""

from __future__ import annotations

from repro.core.lang import (
    Application,
    ClassDef,
    Compute,
    COLLECTION,
    Const,
    ExprStmt,
    FieldSpec,
    ForEach,
    Get,
    Let,
    MethodDef,
    Return,
    This,
    Var,
    While,
    fields_of,
)


def _nearest(dims, centroids):
    best, best_d = 0, float("inf")
    for i, c in enumerate(centroids):
        d = sum((a - b) ** 2 for a, b in zip(dims, c))
        if d < best_d:
            best, best_d = i, d
    return best


def _update_state(state, cluster, dims):
    sums, counts = state
    acc = sums[cluster]
    sums[cluster] = [a + b for a, b in zip(acc, dims)]
    counts[cluster] += 1
    return state


def _recompute(state, centroids):
    sums, counts = state
    return [
        [s / c for s in sums[i]] if (c := counts[i]) else centroids[i]
        for i in range(len(centroids))
    ]


def build_kmeans_app() -> Application:
    job = ClassDef(
        "KMeansJob",
        fields_of(
            FieldSpec("collections", target="VectorCollection", card=COLLECTION),
            FieldSpec("k"),
            FieldSpec("iters"),
        ),
    )
    job.add_method(
        MethodDef(
            "run",
            params=(("centroids", None),),
            body=[
                Let("it", Const(0)),
                While(
                    Compute(lambda it, self_iters: it < self_iters, (Var("it"), Get(This(), "iters")), "lt"),
                    [
                        Let(
                            "state",
                            Compute(
                                lambda cents: ([[0.0] * len(c) for c in cents], [0] * len(cents)),
                                (Var("centroids"),),
                                "zeroState",
                            ),
                        ),
                        ForEach(
                            "vc",
                            This(),
                            "collections",
                            [
                                ForEach(
                                    "v",
                                    Var("vc"),
                                    "vectors",
                                    [
                                        Let("dims", Get(Var("v"), "dims")),
                                        Let(
                                            "cl",
                                            Compute(_nearest, (Var("dims"), Var("centroids")), "nearest"),
                                        ),
                                        ExprStmt(
                                            Compute(
                                                _update_state,
                                                (Var("state"), Var("cl"), Var("dims")),
                                                "accumulate",
                                            )
                                        ),
                                    ],
                                )
                            ],
                        ),
                        Let(
                            "centroids",
                            Compute(_recompute, (Var("state"), Var("centroids")), "recompute"),
                        ),
                        Let("it", Compute(lambda i: i + 1, (Var("it"),), "inc")),
                    ],
                ),
                Return(Var("centroids")),
            ],
        )
    )

    vcoll = ClassDef(
        "VectorCollection", fields_of(FieldSpec("vectors", target="Vector", card=COLLECTION))
    )
    vector = ClassDef("Vector", fields_of(FieldSpec("dims")))

    return Application(
        name="kmeans", classes={c.name: c for c in [job, vcoll, vector]}
    )


def populate_kmeans(store, n_vectors: int = 800, n_collections: int = 4, dims: int = 10, seed: int = 3) -> int:
    import random

    rng = random.Random(seed)
    per = n_vectors // n_collections
    colls = []
    for ci in range(n_collections):
        # one locality group per collection: the iteration scans a whole
        # collection before moving on, so co-locating it keeps each scan
        # on a single Data Service
        vecs = [
            store.put("Vector", {"dims": [rng.random() for _ in range(dims)]},
                      group=f"coll{ci}")
            for _ in range(per)
        ]
        colls.append(store.put("VectorCollection", {"vectors": vecs}, group=f"coll{ci}"))
    return store.put("KMeansJob", {"collections": colls, "k": 4, "iters": 3})


def initial_centroids(k: int = 4, dims: int = 10, seed: int = 5):
    import random

    rng = random.Random(seed)
    return [[rng.random() for _ in range(dims)] for _ in range(k)]
