"""Wordcount benchmark (paper section 7.2.2, Figure 11).

Data model: TextCollection ->> Text ->> Chunk (words live in the chunks);
each Text also references a TextStats single association (what gives ROP a
little to do).  Almost all data is reached through collections, which is why
the paper reports CAPre's largest improvement (>50%) here and why ROP
stagnates at depth 3.
"""

from __future__ import annotations

from collections import Counter

from repro.core.lang import (
    Application,
    ClassDef,
    Compute,
    COLLECTION,
    ExprStmt,
    FieldSpec,
    ForEach,
    Get,
    Let,
    MethodDef,
    Return,
    This,
    Var,
    fields_of,
)


def build_wordcount_app() -> Application:
    job = ClassDef(
        "WCJob",
        fields_of(FieldSpec("collections", target="TextCollection", card=COLLECTION)),
    )
    job.add_method(
        MethodDef(
            "run",
            params=(),
            body=[
                Let("counts", Compute(lambda: Counter(), (), "newCounter")),
                ForEach(
                    "tc",
                    This(),
                    "collections",
                    [
                        ForEach(
                            "t",
                            Var("tc"),
                            "texts",
                            [
                                ExprStmt(Get(Get(Var("t"), "stats"), "lineCount")),
                                ForEach(
                                    "ch",
                                    Var("t"),
                                    "chunks",
                                    [
                                        ExprStmt(
                                            Compute(
                                                lambda c, words: c.update(words),
                                                (Var("counts"), Get(Var("ch"), "words")),
                                                "countWords",
                                            )
                                        )
                                    ],
                                ),
                            ],
                        )
                    ],
                ),
                Return(Var("counts")),
            ],
        )
    )

    text_collection = ClassDef(
        "TextCollection", fields_of(FieldSpec("texts", target="Text", card=COLLECTION))
    )
    text = ClassDef(
        "Text",
        fields_of(
            FieldSpec("chunks", target="Chunk", card=COLLECTION),
            FieldSpec("stats", target="TextStats"),
            FieldSpec("name"),
        ),
    )
    stats = ClassDef("TextStats", fields_of(FieldSpec("lineCount"), FieldSpec("charCount")))
    chunk = ClassDef("Chunk", fields_of(FieldSpec("words")))

    return Application(
        name="wordcount",
        classes={c.name: c for c in [job, text_collection, text, stats, chunk]},
    )


_WORDS = ("the quick brown fox jumps over the lazy dog lorem ipsum dolor sit amet "
          "consectetur adipiscing elit sed do eiusmod tempor incididunt ut labore").split()


def populate_wordcount(
    store,
    n_collections: int = 4,
    texts_per_collection: int = 2,
    chunks_per_text: int = 64,
    words_per_chunk: int = 32,
    seed: int = 11,
) -> int:
    """The paper's setup: files split into collections, distributed across the
    Data Services; the chunk count is the swept parameter."""
    import random

    rng = random.Random(seed)
    collections = []
    for ci in range(n_collections):
        texts = []
        for ti in range(texts_per_collection):
            # one locality group per text: the run scans each text's chunk
            # list end to end, so its closure belongs on one Data Service
            grp = f"t{ci}.{ti}"
            chunks = [
                store.put(
                    "Chunk",
                    {"words": [rng.choice(_WORDS) for _ in range(words_per_chunk)]},
                    group=grp,
                )
                for _ in range(chunks_per_text)
            ]
            st = store.put("TextStats", {"lineCount": chunks_per_text, "charCount": 0},
                           group=grp)
            texts.append(
                store.put("Text", {"chunks": chunks, "stats": st, "name": grp}, group=grp)
            )
        collections.append(store.put("TextCollection", {"texts": texts}))
    return store.put("WCJob", {"collections": collections})
