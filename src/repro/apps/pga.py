"""Princeton Graph Algorithms benchmark (paper section 7.2.4, Figure 15).

Data model: WeightedDirectedGraph ->> Vertex ->> WeightedEdge -> Vertex.

Two algorithms with deliberately different access structure:

  * **DFS** iterates the graph's vertex collection and recursively visits
    along edges — the static analysis sees the collections and prefetches
    them (the paper: "similar to WordCount; CAPre doubles ROP's gain");
  * **Bellman-Ford** (SPFA variant) drives the traversal from a *local
    worklist* seeded with the source vertex — the accessed objects depend on
    run-time relaxation order, so neither CAPre nor ROP can predict them
    (the paper: no significant improvement, but CAPre also adds ~no
    overhead, because it knows there is nothing to prefetch).
"""

from __future__ import annotations

from repro.core.lang import (
    Application,
    Call,
    ClassDef,
    Compute,
    COLLECTION,
    ExprStmt,
    FieldSpec,
    ForEach,
    ForEachLocal,
    Get,
    If,
    Let,
    MethodDef,
    Return,
    This,
    Var,
    While,
    fields_of,
)


def build_pga_app() -> Application:
    graph = ClassDef(
        "WeightedDirectedGraph",
        fields_of(FieldSpec("vertices", target="Vertex", card=COLLECTION), FieldSpec("name")),
    )
    vertex = ClassDef(
        "Vertex",
        fields_of(FieldSpec("edges", target="WeightedEdge", card=COLLECTION), FieldSpec("vid")),
    )
    edge = ClassDef(
        "WeightedEdge",
        fields_of(FieldSpec("toVertex", target="Vertex"), FieldSpec("weight")),
    )

    # ---- DFS ---------------------------------------------------------------
    vertex.add_method(
        MethodDef(
            "visit",
            params=(("marked", None),),
            body=[
                If(
                    Compute(lambda m, v: v in m, (Var("marked"), This()), "seen"),
                    then=[Return(Const0())],
                ),
                ExprStmt(Compute(lambda m, v: m.add(v), (Var("marked"), This()), "mark")),
                Let("acc", Const0()),
                ForEach(
                    "e",
                    This(),
                    "edges",
                    [
                        Let("w", Get(Var("e"), "weight")),
                        Let("nxt", Get(Var("e"), "toVertex")),
                        Let(
                            "acc",
                            Compute(
                                lambda a, w, sub: a + w + sub,
                                (Var("acc"), Var("w"), Call(Var("nxt"), "visit", (Var("marked"),))),
                                "add",
                            ),
                        ),
                    ],
                ),
                Return(Var("acc")),
            ],
        )
    )
    graph.add_method(
        MethodDef(
            "dfs",
            params=(),
            body=[
                Let("marked", Compute(lambda: set(), (), "newSet")),
                Let("acc", Const0()),
                ForEach(
                    "v",
                    This(),
                    "vertices",
                    [
                        Let(
                            "acc",
                            Compute(
                                lambda a, sub: a + sub,
                                (Var("acc"), Call(Var("v"), "visit", (Var("marked"),))),
                                "add",
                            ),
                        )
                    ],
                ),
                Return(Var("acc")),
            ],
        )
    )

    # ---- Bellman-Ford (SPFA): worklist-driven, data-dependent order --------
    graph.add_method(
        MethodDef(
            "bellmanFord",
            params=(("source", "Vertex"),),
            body=[
                Let("dist", Compute(lambda s: {s: 0.0}, (Var("source"),), "initDist")),
                Let("queue", Compute(lambda s: [s], (Var("source"),), "initQueue")),
                While(
                    Compute(lambda q: len(q) > 0, (Var("queue"),), "nonEmpty"),
                    [
                        Let("u", Compute(lambda q: q.pop(0), (Var("queue"),), "pop")),
                        ForEach(
                            "e",
                            Var("u"),
                            "edges",
                            [
                                Let("v2", Get(Var("e"), "toVertex")),
                                Let("w", Get(Var("e"), "weight")),
                                Let(
                                    "relaxed",
                                    Compute(
                                        _relax,
                                        (Var("dist"), Var("u"), Var("v2"), Var("w")),
                                        "relax",
                                    ),
                                ),
                                If(
                                    Var("relaxed"),
                                    then=[
                                        ExprStmt(
                                            Compute(
                                                lambda q, v: q.append(v), (Var("queue"), Var("v2")), "push"
                                            )
                                        )
                                    ],
                                ),
                            ],
                        ),
                    ],
                ),
                Return(Var("dist")),
            ],
        )
    )

    return Application(name="pga", classes={c.name: c for c in [graph, vertex, edge]})


def Const0():
    from repro.core.lang import Const

    return Const(0)


def _relax(dist, u, v, w) -> bool:
    du = dist.get(u)
    if du is None:
        return False
    nd = du + w
    if nd < dist.get(v, float("inf")):
        dist[v] = nd
        return True
    return False


def populate_pga(store, n_vertices: int = 300, out_degree: int = 4, seed: int = 13):
    """Returns (graph_oid, source_vertex_oid)."""
    import random

    rng = random.Random(seed)
    # a vertex and its out-edges form one locality group: relaxing a vertex
    # touches all of them, so co-location spares the per-edge remote hops
    vertices = [store.put("Vertex", {"vid": i, "edges": []}, group=f"v{i}")
                for i in range(n_vertices)]
    for i, v in enumerate(vertices):
        edges = []
        # a ring edge keeps the graph connected; chords add density
        targets = {vertices[(i + 1) % n_vertices]}
        while len(targets) < out_degree:
            targets.add(vertices[rng.randrange(n_vertices)])
        for t in targets:
            edges.append(store.put("WeightedEdge", {"toVertex": t, "weight": rng.random()},
                                   group=f"v{i}"))
        store.peek(v).fields["edges"] = edges
    g = store.put("WeightedDirectedGraph", {"vertices": vertices, "name": "g"})
    return g, vertices[0]
