"""The paper's running example: the bank management system of Figure 1 and
Listing 1, transcribed statement-for-statement into the ``core.lang`` AST."""

from __future__ import annotations

from repro.core.lang import (
    Application,
    Break,
    Call,
    ClassDef,
    Compute,
    COLLECTION,
    ExprStmt,
    FieldSpec,
    ForEach,
    Get,
    If,
    MethodDef,
    Return,
    SetField,
    This,
    Var,
    fields_of,
)


def build_bank_app() -> Application:
    transaction = ClassDef(
        "Transaction",
        fields_of(
            FieldSpec("account", target="Account"),
            FieldSpec("emp", target="Employee"),
            FieldSpec("type", target="TransactionType"),
            FieldSpec("amount"),
        ),
    )
    # public Account getAccount() {
    #   if (this.type.typeID == 1) { this.emp.doSmth(); }
    #   else { this.emp.dept.doSmthElse(); }
    #   return this.account;
    # }
    transaction.add_method(
        MethodDef(
            "getAccount",
            params=(),
            ret_type="Account",
            body=[
                If(
                    cond=Compute(lambda tid: tid == 1, (Get(Get(This(), "type"), "typeID"),), "typeID==1"),
                    then=[ExprStmt(Call(Get(This(), "emp"), "doSmth"))],
                    els=[ExprStmt(Call(Get(Get(This(), "emp"), "dept"), "doSmthElse"))],
                ),
                Return(Get(This(), "account")),
            ],
        )
    )

    ttype = ClassDef("TransactionType", fields_of(FieldSpec("typeID")))

    account = ClassDef("Account", fields_of(FieldSpec("cust", target="Customer"), FieldSpec("balance")))
    # public void setCustomer(Customer newCust) {
    #   if (this.cust.company == newCust.company) { this.cust = newCust; }
    # }
    account.add_method(
        MethodDef(
            "setCustomer",
            params=(("newCust", "Customer"),),
            body=[
                If(
                    cond=Compute(
                        lambda a, b: a == b,
                        (Get(Get(This(), "cust"), "company"), Get(Var("newCust"), "company")),
                        "sameCompany",
                    ),
                    then=[SetField(This(), "cust", Var("newCust"))],
                )
            ],
        )
    )

    customer = ClassDef("Customer", fields_of(FieldSpec("company", target="Company"), FieldSpec("name")))
    company = ClassDef("Company", fields_of(FieldSpec("name")))

    employee = ClassDef("Employee", fields_of(FieldSpec("dept", target="Department"), FieldSpec("name")))
    employee.add_method(MethodDef("doSmth", params=(), body=[ExprStmt(Compute(lambda: None, (), "doSmth"))]))

    department = ClassDef("Department", fields_of(FieldSpec("name")))
    department.add_method(
        MethodDef("doSmthElse", params=(), body=[ExprStmt(Compute(lambda: None, (), "doSmthElse"))])
    )

    bank = ClassDef(
        "BankManagement",
        fields_of(
            FieldSpec("transactions", target="Transaction", card=COLLECTION),
            FieldSpec("manager", target="Customer"),
        ),
    )
    # Read-only traversal over the same navigation chains (used by the
    # accuracy tests: no concurrent mutation of the store).
    bank.add_method(
        MethodDef(
            "auditAll",
            params=(),
            body=[
                ForEach(
                    "trans",
                    This(),
                    "transactions",
                    [
                        ExprStmt(Get(Get(Var("trans"), "type"), "typeID")),
                        ExprStmt(Get(Get(Var("trans"), "emp"), "dept")),
                        ExprStmt(Get(Get(Get(Get(Var("trans"), "account"), "cust"), "company"), "name")),
                    ],
                ),
                ExprStmt(Get(Get(Get(This(), "manager"), "company"), "name")),
            ],
        )
    )
    # public void creditAll(double bonus) {
    #   for (Transaction trans : this.transactions) {
    #     trans.amount = trans.amount + bonus;
    #   }
    # }
    # One unconditional primitive-field write per transaction: the
    # write-dense companion to setAllTransCustomers (whose updates are
    # branch-dependent), used by the write-path accounting tests.
    bank.add_method(
        MethodDef(
            "creditAll",
            params=(("bonus", "double"),),
            body=[
                ForEach(
                    "trans",
                    This(),
                    "transactions",
                    [
                        SetField(
                            Var("trans"),
                            "amount",
                            Compute(
                                lambda a, b: a + b,
                                (Get(Var("trans"), "amount"), Var("bonus")),
                                "plusBonus",
                            ),
                        )
                    ],
                )
            ],
        )
    )
    # public void findLargeTransaction(double floor) {
    #   for (Transaction trans : this.transactions) {
    #     if (trans.amount >= floor) { trans.account.cust; break; }
    #   }
    # }
    # An early-exit scan: the break taints the loop, so the static
    # optimizer's partial-traversal pass marks the transactions[] hint with
    # a prefix bound instead of predicting the whole collection.
    bank.add_method(
        MethodDef(
            "findLargeTransaction",
            params=(("floor", "double"),),
            body=[
                ForEach(
                    "trans",
                    This(),
                    "transactions",
                    [
                        If(
                            cond=Compute(
                                lambda a, f: a >= f,
                                (Get(Var("trans"), "amount"), Var("floor")),
                                "overFloor",
                            ),
                            then=[
                                ExprStmt(Get(Get(Var("trans"), "account"), "cust")),
                                Break(),
                            ],
                        )
                    ],
                )
            ],
        )
    )
    # public void setAllTransCustomers() {
    #   for (Transaction trans : this.transactions) {
    #     trans.getAccount().setCustomer(this.manager);
    #   }
    # }
    bank.add_method(
        MethodDef(
            "setAllTransCustomers",
            params=(),
            body=[
                ForEach(
                    "trans",
                    This(),
                    "transactions",
                    [
                        ExprStmt(
                            Call(
                                Call(Var("trans"), "getAccount"),
                                "setCustomer",
                                (Get(This(), "manager"),),
                            )
                        )
                    ],
                )
            ],
        )
    )

    return Application(
        name="bank",
        classes={
            c.name: c
            for c in [transaction, ttype, account, customer, company, employee, department, bank]
        },
    )


def populate_bank_store(store, n_transactions: int = 100, n_companies: int = 3, seed: int = 0):
    """Store a bank dataset; returns the BankManagement root oid."""
    import random

    rng = random.Random(seed)
    companies = [store.put("Company", {"name": f"co{i}"}) for i in range(n_companies)]
    manager_co = companies[0]
    manager = store.put("Customer", {"company": manager_co, "name": "manager"})
    depts = [store.put("Department", {"name": f"dept{i}"}) for i in range(4)]
    ttypes = [store.put("TransactionType", {"typeID": i}) for i in (1, 2)]
    transactions = []
    for i in range(n_transactions):
        comp = rng.choice(companies)
        # each transaction's navigation closure (tx -> account -> customer,
        # tx -> employee) is one locality group: a locality-aware placement
        # co-locates the whole hop chain on one Data Service
        cust = store.put("Customer", {"company": comp, "name": f"cust{i}"}, group=f"tx{i}")
        acct = store.put("Account", {"cust": cust, "balance": float(i)}, group=f"tx{i}")
        emp = store.put("Employee", {"dept": rng.choice(depts), "name": f"emp{i}"}, group=f"tx{i}")
        tx = store.put(
            "Transaction",
            {"account": acct, "emp": emp, "type": rng.choice(ttypes), "amount": float(i)},
            group=f"tx{i}",
        )
        transactions.append(tx)
    root = store.put("BankManagement", {"transactions": transactions, "manager": manager})
    return root
