"""Checkpointing for fault-tolerant training at pod scale.

Design (what a 1000-node deployment needs, implemented and tested here):

  * **atomic**: a checkpoint directory is written under ``step_N.tmp`` and
    renamed to ``step_N`` only after every shard file and the manifest are
    durably on disk — a crash mid-save never corrupts the latest checkpoint;
  * **async**: ``save(...)`` snapshots the arrays (device->host) on the
    caller thread, then writes in a background thread so the train loop
    keeps stepping (the CAPre philosophy again: overlap I/O with compute);
  * **integrity**: every leaf file carries a crc32; the manifest records the
    tree structure, shapes, dtypes and per-leaf checksums; restore verifies;
  * **keep-k GC**: old steps are garbage-collected after a successful save;
  * **elastic restore**: ``restore(..., shardings=...)`` re-lays the arrays
    onto ANY mesh (different device count than at save time) via
    ``jax.device_put`` — recover from a 512-chip checkpoint onto 256 chips
    after losing a pod, or vice versa.

On a multi-host deployment each host writes only the shards it owns
(``process_index`` namespacing is in place); in this single-process harness
that is one writer.
"""

from __future__ import annotations

import json
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


class CheckpointError(RuntimeError):
    pass


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.process_index = jax.process_index()

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any, *, wait: bool = False) -> None:
        """Checkpoint a pytree at ``step``.  Snapshots synchronously (cheap),
        writes asynchronously unless ``wait``/sync mode."""
        self.wait()  # one outstanding save at a time; surfaces prior errors
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        snapshot = [(self._path_str(p), np.asarray(v)) for p, v in leaves]
        treedef_repr = str(treedef)

        def write():
            try:
                self._write(step, snapshot, treedef_repr)
            except BaseException as e:  # pragma: no cover
                self._error = e

        if self.async_save and not wait:
            self._thread = threading.Thread(target=write, name=f"ckpt-save-{step}")
            self._thread.start()
        else:
            write()
            if self._error:
                e, self._error = self._error, None
                raise e

    def _write(self, step: int, snapshot, treedef_repr: str) -> None:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp.{self.process_index}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "treedef": treedef_repr, "leaves": [], "time": time.time()}
        for i, (path, arr) in enumerate(snapshot):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr, allow_pickle=False)
            manifest["leaves"].append(
                {
                    "path": path,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
                }
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # the atomic commit
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise CheckpointError(f"async save failed: {e!r}") from e

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.name.endswith(".tmp") or ".tmp." in p.name:
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, like: Any = None, shardings: Any = None) -> tuple[int, Any]:
        """Restore (step, tree).  ``like`` provides the tree structure (its
        leaf order must match the saved manifest paths); ``shardings`` (an
        optional matching tree of NamedSharding) re-lays leaves onto the
        current mesh — elastic restore across different mesh shapes."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise CheckpointError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        arrays: dict[str, np.ndarray] = {}
        for leaf in manifest["leaves"]:
            arr = np.load(d / leaf["file"], allow_pickle=False)
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
            if crc != leaf["crc32"]:
                raise CheckpointError(f"crc mismatch for {leaf['path']} in step {step}")
            if list(arr.shape) != leaf["shape"]:
                raise CheckpointError(f"shape mismatch for {leaf['path']}")
            arrays[leaf["path"]] = arr

        if like is None:
            return step, arrays

        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for p, ref in leaves:
            key = self._path_str(p)
            if key not in arrays:
                raise CheckpointError(f"missing leaf {key} in checkpoint step {step}")
            arr = arrays[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise CheckpointError(
                    f"leaf {key}: checkpoint shape {arr.shape} != expected {ref.shape}"
                )
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return step, tree

    @staticmethod
    def _path_str(path) -> str:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return ".".join(parts)
