"""Shared model utilities: logical-axis sharding constraints and the
parameter-template mechanism (single source of truth for parameter shapes,
initializers, logical sharding axes, and abstract ShapeDtypeStructs)."""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Logical-axis sharding constraints
# ---------------------------------------------------------------------------
# Model code annotates activations with *logical* axes ("batch", "embed",
# "heads", ...).  The launcher activates a (mesh, rules) context mapping
# logical axes to mesh axes; outside a context the annotations are no-ops, so
# the same model code runs on one CPU device and on the production mesh.

_CTX = threading.local()


@contextmanager
def activate_sharding(mesh, rules: dict[str, Optional[object]]):
    prev = getattr(_CTX, "ctx", None)
    _CTX.ctx = (mesh, rules)
    try:
        yield
    finally:
        _CTX.ctx = prev


def current_mesh_rules():
    return getattr(_CTX, "ctx", None)


def constrain(x, *axes: Optional[str]):
    """with_sharding_constraint by logical axis names (None = unsharded dim)."""
    ctx = getattr(_CTX, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = P(*[rules.get(a) if a is not None else None for a in axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def logical_to_pspec(axes: tuple, rules: dict) -> P:
    return P(*[rules.get(a) if a is not None else None for a in axes])


# ---------------------------------------------------------------------------
# Parameter templates
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axis per dim
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02

    def abstract(self, dtype) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, dtype)


def init_from_template(template, rng: jax.Array, dtype) -> dict:
    """Materialize a parameter pytree from a template of ParamSpecs."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            out.append(spec.scale * jax.random.normal(key, spec.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_from_template(template, dtype) -> dict:
    return jax.tree.map(
        lambda s: s.abstract(dtype), template, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def pspecs_from_template(template, rules: dict) -> dict:
    return jax.tree.map(
        lambda s: logical_to_pspec(s.axes, rules),
        template,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_bytes(template, bytes_per_el: int = 4) -> int:
    total = 0
    for s in jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, ParamSpec)):
        n = 1
        for d in s.shape:
            n *= d
        total += n * bytes_per_el
    return total


def param_count(template) -> int:
    total = 0
    for s in jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, ParamSpec)):
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total
