"""Transformer assembly: blocks, scanned layer stacks, and decode paths for
all five families (dense / moe / ssm / hybrid / encdec).

Layer parameters are stacked on a leading ``layers`` dim and consumed with
``lax.scan`` (keeps the HLO small at 60+ layers); the hybrid family's
interleaved (rec, rec, attn) pattern uses a python loop over per-layer
slices instead.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .common import constrain
from .layers import (
    apply_norm,
    apply_rope,
    attn_output,
    gqa_attention,
    mlp_apply,
    qkv_project,
)
from .moe import moe_apply
from .rglru import recurrent_block
from .ssm import mamba_block


def _remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if cfg.remat == "save_collectives":
        # full remat EXCEPT collective outputs (MoE a2a results): recompute
        # compute-cheap work, never re-pay the wire
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names("moe_out")
        )
    return fn


# ---------------------------------------------------------------------------
# Blocks (full-sequence)
# ---------------------------------------------------------------------------


def attn_block(x, lp, cfg, dt, positions, *, causal=True, local_window=0,
               cross_kv=None, collect_cache=False):
    """Pre-norm attention sub-block. Returns (x, (k, v) or None)."""
    h = apply_norm(cfg.norm, x, lp["ln1"], lp.get("ln1_b"))
    # pin the (bf16) norm output to the residual layout so under SP the
    # all-gather crosses in bf16, not the norm's f32 internals (§Perf It3)
    h = constrain(h, "batch", "seq", "embed")
    q, k, v = qkv_project(h, lp["attn"], cfg, dt)
    if cross_kv is None:
        q = apply_rope(cfg.rope, q, positions, cfg.rope_theta)
        k = apply_rope(cfg.rope, k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv
        causal = False
    q = constrain(q, "batch", "inner_seq", "act_heads", None)
    k = constrain(k, "batch", "inner_seq", "act_kv", None)
    o = gqa_attention(
        q, k, v, causal=causal, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
        local_window=local_window,
    )
    x = x + attn_output(o, lp["attn"], cfg, dt)
    x = constrain(x, "batch", "seq", "embed")
    return x, ((k, v) if collect_cache else None)


def ffn_block(x, lp, cfg, dt, mesh_info):
    h = apply_norm(cfg.norm, x, lp["ln2"], lp.get("ln2_b"))
    h = constrain(h, "batch", "seq", "embed")
    if cfg.family == "moe":
        y = moe_apply(h, lp["mlp"], cfg, dt, mesh_info)
    else:
        y = mlp_apply(cfg.mlp, h, lp["mlp"], dt)
    x = x + y
    return constrain(x, "batch", "seq", "embed")


def dense_layer(x, lp, cfg, dt, positions, mesh_info, *, causal=True,
                local_window=0, collect_cache=False):
    x, kv = attn_block(
        x, lp, cfg, dt, positions, causal=causal, local_window=local_window,
        collect_cache=collect_cache,
    )
    x = ffn_block(x, lp, cfg, dt, mesh_info)
    return x, kv


def mamba_layer(x, lp, cfg, dt, collect_cache=False):
    h = apply_norm(cfg.norm, x, lp["ln1"], lp.get("ln1_b"))
    y, conv_state, ssm_state = mamba_block(h, lp["mamba"], cfg, dt)
    x = constrain(x + y, "batch", "seq", "embed")
    return x, ((conv_state, ssm_state) if collect_cache else None)


def rec_layer(x, lp, cfg, dt, collect_cache=False):
    h = apply_norm(cfg.norm, x, lp["ln1"], lp.get("ln1_b"))
    y, conv_state, rec_state = recurrent_block(h, lp["rec"], cfg, dt)
    x = constrain(x + y, "batch", "seq", "embed")
    x = ffn_block(x, lp, cfg, dt, None)
    return x, ((conv_state, rec_state) if collect_cache else None)


# ---------------------------------------------------------------------------
# Stacks (full-sequence forward; optionally collect decode caches)
# ---------------------------------------------------------------------------


def scan_stack(x, layers, body):
    """lax.scan over stacked layer params; body(x, lp) -> (x, extras)."""
    def f(carry, lp):
        return body(carry, lp)

    return jax.lax.scan(f, x, layers)


def forward_stack(params, cfg, x, positions, mesh_info, *, causal=True,
                  collect_cache=False):
    """Homogeneous stacks (dense / moe / ssm)."""
    if cfg.family == "ssm":
        body = _remat(lambda h, lp: mamba_layer(h, lp, cfg, cfg_dtype(cfg), collect_cache), cfg)
    else:
        body = _remat(
            lambda h, lp: dense_layer(
                h, lp, cfg, cfg_dtype(cfg), positions, mesh_info,
                causal=causal, collect_cache=collect_cache,
            ),
            cfg,
        )
    x, extras = scan_stack(x, params["layers"], body)
    return x, extras


def forward_hybrid(params, cfg, x, positions, mesh_info, *, collect_cache=False):
    """recurrentgemma: python loop over the (rec, rec, attn) pattern."""
    dt = cfg_dtype(cfg)
    rec_i = attn_i = 0
    rec_caches, attn_caches = [], []
    for li in range(cfg.n_layers):
        kind = cfg.block_pattern[li % len(cfg.block_pattern)]
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[rec_i], params["rec_layers"])
            fn = _remat(lambda h, lp=lp: rec_layer(h, lp, cfg, dt, collect_cache), cfg)
            x, cache = fn(x)
            rec_caches.append(cache)
            rec_i += 1
        else:
            lp = jax.tree.map(lambda a: a[attn_i], params["attn_layers"])

            def attn_fn(h, lp=lp):
                h, kv = dense_layer(
                    h, lp, cfg, dt, positions, mesh_info,
                    causal=True, local_window=cfg.local_window,
                    collect_cache=collect_cache,
                )
                return h, kv

            x, kv = _remat(attn_fn, cfg)(x)
            attn_caches.append(kv)
            attn_i += 1
    if not collect_cache:
        return x, None
    stack = lambda caches: jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    return x, (stack(rec_caches), stack(attn_caches))


def forward_encoder(params, cfg, frames, mesh_info):
    """whisper encoder over precomputed (stub) frame embeddings."""
    from .layers import sinusoidal_embedding

    dt = cfg_dtype(cfg)
    B, F, d = frames.shape
    pos = jnp.arange(F)[None, :]
    x = frames.astype(dt) + sinusoidal_embedding(pos, d).astype(dt)
    body = _remat(
        lambda h, lp: dense_layer(h, lp, cfg, dt, pos, mesh_info, causal=False),
        cfg,
    )
    x, _ = scan_stack(x, params["enc_layers"], body)
    return apply_norm(cfg.norm, x, params["enc_norm"], params.get("enc_norm_b"))


def forward_decoder(params, cfg, x, positions, enc_out, mesh_info, *,
                    collect_cache=False):
    """whisper decoder: self-attention + cross-attention per layer."""
    dt = cfg_dtype(cfg)

    def body(h, lp):
        h, self_kv = attn_block(
            h, lp, cfg, dt, positions, causal=True, collect_cache=collect_cache
        )
        # cross-attention: kv projected from encoder output
        hc = apply_norm(cfg.norm, h, lp["lnc"], lp.get("lnc_b"))
        cast = lambda w: w.astype(dt)
        cp = lp["cross"]
        kc = enc_out @ cast(cp["wk"])
        vc = enc_out @ cast(cp["wv"])
        Bq = hc.shape[0]
        kc = kc.reshape(Bq, -1, cfg.n_kv_heads, cfg.head_dim)
        vc = vc.reshape(Bq, -1, cfg.n_kv_heads, cfg.head_dim)
        qc = (hc @ cast(cp["wq"])).reshape(Bq, -1, cfg.n_heads, cfg.head_dim)
        oc = gqa_attention(qc, kc, vc, causal=False, impl=cfg.attn_impl, chunk=cfg.attn_chunk)
        h = h + oc.reshape(Bq, -1, cfg.q_dim) @ cast(cp["wo"])
        h = ffn_block(h, lp, cfg, dt, mesh_info)
        extras = (self_kv, (kc, vc)) if collect_cache else None
        return h, extras

    return scan_stack(x, params["dec_layers"], _remat(body, cfg))


# ---------------------------------------------------------------------------
# Decode (single token against a cache)
# ---------------------------------------------------------------------------


def _decode_attn(x, lp, cfg, dt, k_cache, v_cache, pos, *, window: int = 0):
    """One-token attention against a cache [B, S, KV, hd]; writes the new
    k/v at ``pos`` (or ``pos % window`` for ring caches) and attends."""
    B = x.shape[0]
    h = apply_norm(cfg.norm, x, lp["ln1"], lp.get("ln1_b"))
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions, (3, B, 1))
    q, k, v = qkv_project(h, lp["attn"], cfg, dt)
    q = apply_rope(cfg.rope, q, positions, cfg.rope_theta)
    k = apply_rope(cfg.rope, k, positions, cfg.rope_theta)
    slot = pos % window if window else pos
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    if window:
        # ring buffer: mask by stored-position recency
        S = k_cache.shape[1]
        idx = jnp.arange(S)
        ring_pos = pos - ((slot - idx) % S)  # absolute position stored per slot
        valid = (ring_pos >= 0) & (ring_pos >= pos - window + 1)
        o = _masked_decode_attention(q, k_cache, v_cache, valid, cfg)
    elif cfg.attn_impl == "pallas" and k_cache.shape[1] % 128 == 0:
        # flash-decode kernel: the KV cache streams HBM->VMEM once (in its
        # stored dtype — fp8 caches halve the traffic), scores stay in VMEM
        from repro.kernels.ops import decode_attention as _dec

        o = _dec(q[:, 0], k_cache, v_cache, pos + 1).astype(dt)[:, None]
    else:
        o = gqa_attention(
            q, k_cache.astype(dt), v_cache.astype(dt), causal=False,
            impl="naive", q_offset=pos, kv_len=pos + 1,
        )
    x = x + attn_output(o, lp["attn"], cfg, dt)
    return x, k_cache, v_cache


def _masked_decode_attention(q, k_cache, v_cache, valid, cfg):
    B, S, KV, hd = k_cache.shape
    H = cfg.n_heads
    G = H // KV
    q5 = q.reshape(B, 1, KV, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q5, k_cache.astype(q.dtype),
                   preferred_element_type=jnp.float32) / (hd**0.5)
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(q.dtype))
    return o.reshape(B, 1, H, hd)


def decode_stack(params, cfg, x, cache, pos, mesh_info):
    """dense / moe decode: scan over (layer params, cache layers)."""
    dt = cfg_dtype(cfg)

    def body(h, inp):
        lp, kc, vc = inp
        h, kc, vc = _decode_attn(h, lp, cfg, dt, kc, vc, pos)
        h = ffn_block(h, lp, cfg, dt, mesh_info)
        return h, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    return x, {"k": k_new, "v": v_new}


def decode_ssm(params, cfg, x, cache, mesh_info):
    dt = cfg_dtype(cfg)

    def body(h, inp):
        lp, conv_s, ssm_s = inp
        hn = apply_norm(cfg.norm, h, lp["ln1"], lp.get("ln1_b"))
        y, new_conv, new_ssm = mamba_block(
            hn, lp["mamba"], cfg, dt, conv_state=conv_s, ssm_state=ssm_s
        )
        return h + y, (new_conv, new_ssm)

    x, (conv_new, ssm_new) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"])
    )
    return x, {"conv": conv_new, "ssm": ssm_new}


def decode_hybrid(params, cfg, x, cache, pos, mesh_info):
    dt = cfg_dtype(cfg)
    rec_i = attn_i = 0
    new_conv, new_rec, new_k, new_v = [], [], [], []
    for li in range(cfg.n_layers):
        kind = cfg.block_pattern[li % len(cfg.block_pattern)]
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[rec_i], params["rec_layers"])
            hn = apply_norm(cfg.norm, x, lp["ln1"], lp.get("ln1_b"))
            from .rglru import recurrent_block

            y, cs, rs = recurrent_block(
                hn, lp["rec"], cfg, dt,
                conv_state=cache["conv"][rec_i], rec_state=cache["rec"][rec_i],
            )
            x = x + y
            x = ffn_block(x, lp, cfg, dt, None)
            new_conv.append(cs)
            new_rec.append(rs)
            rec_i += 1
        else:
            lp = jax.tree.map(lambda a: a[attn_i], params["attn_layers"])
            x, kc, vc = _decode_attn(
                x, lp, cfg, dt, cache["k"][attn_i], cache["v"][attn_i], pos,
                window=cfg.local_window,
            )
            x = ffn_block(x, lp, cfg, dt, None)
            new_k.append(kc)
            new_v.append(vc)
            attn_i += 1
    return x, {
        "conv": jnp.stack(new_conv),
        "rec": jnp.stack(new_rec),
        "k": jnp.stack(new_k),
        "v": jnp.stack(new_v),
    }


def decode_encdec(params, cfg, x, cache, pos, mesh_info):
    """whisper decode: self-attn against the self cache + cross-attn against
    the prefilled cross kv."""
    dt = cfg_dtype(cfg)

    def body(h, inp):
        lp, kc, vc, ck, cv = inp
        h, kc, vc = _decode_attn(h, lp, cfg, dt, kc, vc, pos)
        hc = apply_norm(cfg.norm, h, lp["lnc"], lp.get("lnc_b"))
        cast = lambda w: w.astype(dt)
        cp = lp["cross"]
        B = hc.shape[0]
        qc = (hc @ cast(cp["wq"])).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        oc = gqa_attention(qc, ck.astype(dt), cv.astype(dt), causal=False, impl="naive")
        h = h + oc.reshape(B, 1, cfg.q_dim) @ cast(cp["wo"])
        h = ffn_block(h, lp, cfg, dt, mesh_info)
        return h, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"]),
    )
    return x, {"k": k_new, "v": v_new, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}


def cfg_dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)
