"""Mixture-of-Experts layer with expert parallelism.

CAPre mapping (DESIGN.md section 2): the router's top-k choice is the
paper's *branch-dependent navigation* — which expert weights a token touches
is decided at run time.  CAPre's policy is to prefetch the union of branches;
here the full expert bank is the statically-known superset, staged
expert-parallel across the ``model`` mesh axis.

Two execution paths, same math:

  * ``moe_apply_dense`` — single-device / smoke-test path: capacity-based
    one-hot dispatch einsums (no collectives);
  * ``moe_apply_ep``    — shard_map path: activations arrive replicated over
    the ``model`` axis (the standard 2D layout for the attention TP blocks),
    so each model shard routes all of its data-shard's tokens but dispatches
    **only to its local expert slice** (E/n_model experts); the combine is a
    single psum over ``model``.  Dispatch-matmul cost per shard is
    T_local * E_local * C * d — 1/n_model of the dense path — and the only
    collective is the [T, d] psum (same volume as a Megatron MLP reduce).

An all-to-all token-exchange variant (tokens sharded over ``model`` too) is
a recorded hillclimb candidate in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def router_topk(x2d, router_w, n_experts: int, k: int, router_dtype=jnp.float32):
    """x2d [T, d] -> (probs [T, k], idx [T, k]) with softmax-renormalized
    top-k gates (qwen3/granite style: softmax over all experts, keep top-k)."""
    logits = x2d.astype(router_dtype) @ router_w.astype(router_dtype)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i


def _dispatch_onehot(top_i, top_p, n_experts: int, capacity: int):
    """Build dispatch/combine tensors [T, E, C].

    Position within an expert's capacity buffer is the token's rank among
    tokens routed to that expert (overflow dropped).  Out-of-range expert
    indices (the EP path passes shifted local indices) one-hot to zero rows,
    which drops them for free."""
    T, k = top_i.shape
    oh = jax.nn.one_hot(top_i, n_experts, dtype=jnp.float32)  # [T, k, E]
    flat = oh.reshape(T * k, n_experts)
    ranks = (jnp.cumsum(flat, axis=0) - flat).reshape(T, k, n_experts)
    in_cap = ranks < capacity
    pos = jnp.where(in_cap, ranks, 0).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32) * in_cap[..., None] * oh[..., None]
    # pos_oh: [T, k, E, C]
    disp = pos_oh.sum(axis=1)
    comb = jnp.einsum("tkec,tk->tec", pos_oh, top_p.astype(jnp.float32))
    return disp, comb


def _expert_ffn(xe, we_gate, we_up, we_down, compute_dtype):
    """xe [E, C, d] -> [E, C, d] with per-expert gated MLP."""
    cast = lambda w: w.astype(compute_dtype)
    g = jnp.einsum("ecd,edf->ecf", xe, cast(we_gate))
    u = jnp.einsum("ecd,edf->ecf", xe, cast(we_up))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, cast(we_down))


def _dispatch_scatter(x2, local_i, top_p, n_local: int, cap: int, compute_dtype):
    """Scatter-based dispatch (§Perf hillclimb variant): instead of the
    one-hot [T, E, C] matmuls (O(T^2) FLOPs via C ~ T), compute each
    (token, slot) rank with a cumsum over one-hot (cheap: no *d factor) and
    scatter rows directly into the [E*C, d] buffer; the combine gathers
    back.  Data movement O(T*k*d), no dispatch matmul."""
    T, k = local_i.shape
    oh = jax.nn.one_hot(local_i, n_local, dtype=jnp.float32)  # [T, k, E]
    flat = oh.reshape(T * k, n_local)
    ranks = (jnp.cumsum(flat, axis=0) - flat).reshape(T, k, n_local)
    rank = jnp.einsum("tke,tke->tk", ranks, oh).astype(jnp.int32)  # [T, k]
    valid = (local_i >= 0) & (local_i < n_local) & (rank < cap)
    slot = jnp.where(valid, local_i * cap + rank, n_local * cap)  # overflow row
    buf = jnp.zeros((n_local * cap + 1, x2.shape[1]), compute_dtype)
    xk = jnp.broadcast_to(x2[:, None, :], (T, k, x2.shape[1]))
    buf = buf.at[slot.reshape(-1)].set(xk.reshape(T * k, -1), mode="drop")
    return buf[:-1], slot, valid, rank


def _route_dispatch_ffn(x2, router_w, we_gate, we_up, we_down, cfg, compute_dtype,
                        expert_offset: int = 0, n_local: int = 0):
    """Shared core: route tokens in chunks, dispatch each chunk to the
    expert slice [expert_offset, expert_offset + n_local), run the expert
    FFN, combine.  Chunking bounds the [T, E, C] dispatch tensors (C scales
    with the chunk size).  Returns the (partial) output [T, d]."""
    E, k = cfg.n_experts, cfg.experts_per_token
    n_local = n_local or E
    T, d = x2.shape
    chunk = min(cfg.moe_chunk, T)
    while T % chunk:
        chunk //= 2
    n_chunks = T // chunk
    cap = max(1, int(cfg.capacity_factor * chunk * k / E))

    def one_chunk(xc):
        top_p, top_i = router_topk(xc, router_w, E, k)
        local_i = top_i - expert_offset  # out-of-slice -> out-of-range -> dropped
        if cfg.moe_dispatch == "scatter":
            xe_flat, slot, valid, _ = _dispatch_scatter(
                xc, local_i, top_p, n_local, cap, compute_dtype
            )
            xe = xe_flat.reshape(n_local, cap, d)
            ye = _expert_ffn(xe, we_gate, we_up, we_down, compute_dtype)
            ye_flat = ye.reshape(n_local * cap, d)
            gathered = jnp.take(ye_flat, jnp.where(valid, slot, 0), axis=0)  # [T,k,d]
            w = jnp.where(valid, top_p, 0.0).astype(compute_dtype)
            return jnp.einsum("tkd,tk->td", gathered, w)
        disp, comb = _dispatch_onehot(local_i, top_p, n_local, cap)
        xe = jnp.einsum("tec,td->ecd", disp.astype(compute_dtype), xc)
        ye = _expert_ffn(xe, we_gate, we_up, we_down, compute_dtype)
        return jnp.einsum("tec,ecd->td", comb.astype(compute_dtype), ye)

    if n_chunks == 1:
        return one_chunk(x2)
    xc = x2.reshape(n_chunks, chunk, d)
    yc = jax.lax.map(one_chunk, xc)
    return yc.reshape(T, d)


def moe_apply_dense(x, p, cfg, compute_dtype):
    """Single-shard reference path. x [B, S, d]."""
    B, S, d = x.shape
    x2 = x.reshape(B * S, d)
    y = _route_dispatch_ffn(
        x2, p["router"], p["we_gate"], p["we_up"], p["we_down"], cfg, compute_dtype
    )
    return y.reshape(B, S, d)


def moe_apply_ep(x, p, cfg, compute_dtype, mesh, data_axes, model_axis: str):
    """Expert-parallel path under shard_map (see module docstring)."""
    E = cfg.n_experts
    n_model = mesh.shape[model_axis]
    E_local = E // n_model

    def body(xl, router_w, we_gate, we_up, we_down):
        Bl, S, d = xl.shape
        x2 = xl.reshape(Bl * S, d)
        offset = jax.lax.axis_index(model_axis) * E_local
        y = _route_dispatch_ffn(
            x2, router_w, we_gate, we_up, we_down, cfg, compute_dtype,
            expert_offset=offset, n_local=E_local,
        )
        y = jax.lax.psum(y, model_axis)
        return y.reshape(Bl, S, d)

    from repro.launch.compat import shard_map

    dspec = P(data_axes, None, None)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            dspec,
            P(None, None),  # router replicated
            P(model_axis, None, None),  # expert banks sharded over model
            P(model_axis, None, None),
            P(model_axis, None, None),
        ),
        out_specs=dspec,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])


def moe_apply_fsdp(x, p, cfg, compute_dtype, mesh, batch_axes):
    """FSDP-local path: tokens never leave their device; the expert banks
    arrive via the shard_map replication gather (the per-layer FSDP weight
    all-gather) and every device runs the full dense dispatch on its local
    tokens — routing/dispatch math is entirely collective-free."""
    from repro.launch.compat import shard_map

    def body(xl, router_w, wg, wu, wd):
        Bl, S, d = xl.shape
        y = _route_dispatch_ffn(
            xl.reshape(Bl * S, d), router_w, wg, wu, wd, cfg, compute_dtype
        )
        return y.reshape(Bl, S, d)

    bspec = P(batch_axes, None, None)
    rep2, rep3 = P(None, None), P(None, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(bspec, rep2, rep3, rep3, rep3),
        out_specs=bspec,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])


def moe_apply_ep_a2a(x, p, cfg, compute_dtype, mesh, batch_axes, model_axis):
    """Switch/DeepSpeed-style expert parallelism: tokens sharded over every
    mesh axis; each device routes its own tokens (scatter dispatch, no
    dispatch matmul, no replication) and exchanges capacity buffers with the
    expert shards via all-to-all over ``model``.  Collective payload is the
    [E, C_local, d] activation buffer — independent of the expert bank size."""
    from repro.launch.compat import shard_map

    E, k = cfg.n_experts, cfg.experts_per_token
    n_model = mesh.shape[model_axis]
    E_local = E // n_model

    def body(xl, router_w, wg, wu, wd):
        Bl, S, d = xl.shape
        x2 = xl.reshape(Bl * S, d)
        T = x2.shape[0]
        cap = max(1, int(cfg.capacity_factor * T * k / E))
        top_p, top_i = router_topk(x2, router_w, E, k)
        buf, slot, valid, _ = _dispatch_scatter(x2, top_i, top_p, E, cap, compute_dtype)
        xe = buf.reshape(E, cap, d)
        # exchange: shard m receives every origin's buffers for its experts
        # (tiled all-to-all: expert-block dim scatters, capacity dim gathers)
        xe = jax.lax.all_to_all(xe, model_axis, split_axis=0, concat_axis=1, tiled=True)
        ye = _expert_ffn(xe, wg, wu, wd, compute_dtype)  # [E_local, n*cap, d]
        ye = jax.lax.all_to_all(ye, model_axis, split_axis=1, concat_axis=0, tiled=True)
        ye_flat = jnp.concatenate([ye.reshape(E * cap, d),
                                   jnp.zeros((1, d), compute_dtype)], axis=0)
        gathered = jnp.take(ye_flat, jnp.where(valid, slot, E * cap), axis=0)
        w = jnp.where(valid, top_p, 0.0).astype(compute_dtype)
        y = jnp.einsum("tkd,tk->td", gathered.reshape(T, k, d), w)
        return y.reshape(Bl, S, d)

    bspec = P(batch_axes, None, None)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=bspec,
    )(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
    # under remat="dots_collectives" the saved name keeps the backward from
    # re-running the all-to-alls (collectives are the scarce resource)
    from jax.ad_checkpoint import checkpoint_name

    return checkpoint_name(out, "moe_out")


def moe_apply(x, p, cfg, compute_dtype, mesh_info=None):
    """Dispatch to the dense / EP-psum / fsdp-local / EP-a2a implementation."""
    if mesh_info is not None:
        mesh, data_axes, model_axis = mesh_info[:3]
        mode = mesh_info[3] if len(mesh_info) > 3 else "ep_psum"
        if model_axis is None:
            return moe_apply_fsdp(x, p, cfg, compute_dtype, mesh, data_axes)
        if mesh.shape[model_axis] > 1 and cfg.n_experts % mesh.shape[model_axis] == 0:
            if mode == "ep_a2a":
                return moe_apply_ep_a2a(
                    x, p, cfg, compute_dtype, mesh, data_axes, model_axis
                )
            return moe_apply_ep(x, p, cfg, compute_dtype, mesh, data_axes, model_axis)
    return moe_apply_dense(x, p, cfg, compute_dtype)
