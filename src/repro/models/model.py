"""Model facade: parameter templates, init, loss / prefill / decode entry
points for every architecture family.

The parameter *template* (``build_template``) is the single source of truth
for parameter shapes, initializers and logical sharding axes; it backs
``init_params`` (real arrays), ``abstract_params`` (ShapeDtypeStructs for
the dry-run) and ``param_pspecs`` (PartitionSpecs for pjit) — plus the
CAPre access-plan analysis, which walks the same tree."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .common import (
    ParamSpec,
    abstract_from_template,
    constrain,
    init_from_template,
    param_count,
    pspecs_from_template,
)
from .layers import sinusoidal_embedding
from .transformer import (
    cfg_dtype,
    decode_encdec,
    decode_hybrid,
    decode_ssm,
    decode_stack,
    forward_decoder,
    forward_encoder,
    forward_hybrid,
    forward_stack,
)

# ---------------------------------------------------------------------------
# Parameter templates
# ---------------------------------------------------------------------------


def _stack(tmpl: dict, n: int) -> dict:
    """Add a leading stacked-layers dim to every ParamSpec."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        tmpl,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _attn_tmpl(cfg: ModelConfig, cross: bool = False) -> dict:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    t = {
        "wq": ParamSpec((d, qd), ("embed", "heads")),
        "wk": ParamSpec((d, kvd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, kvd), ("embed", "kv_heads")),
        "wo": ParamSpec((qd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        t["bq"] = ParamSpec((qd,), ("heads",), init="zeros")
        t["bk"] = ParamSpec((kvd,), ("kv_heads",), init="zeros")
        t["bv"] = ParamSpec((kvd,), ("kv_heads",), init="zeros")
    if cfg.attn_out_bias and not cross:
        t["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    if cfg.qk_norm and not cross:
        t["q_norm"] = ParamSpec((hd,), (None,), init="ones")
        t["k_norm"] = ParamSpec((hd,), (None,), init="ones")
    return t


def _norm_tmpl(cfg: ModelConfig, name: str) -> dict:
    t = {name: ParamSpec((cfg.d_model,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        t[f"{name}_b"] = ParamSpec((cfg.d_model,), ("embed",), init="zeros")
    return t


def _mlp_tmpl(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        t = {
            "wi_gate": ParamSpec((d, f), ("embed", "ff")),
            "wi_up": ParamSpec((d, f), ("embed", "ff")),
            "wo": ParamSpec((f, d), ("ff", "embed")),
        }
    else:  # gelu / relu2
        t = {
            "wi": ParamSpec((d, f), ("embed", "ff")),
            "wo": ParamSpec((f, d), ("ff", "embed")),
        }
        if cfg.mlp_bias:
            t["bi"] = ParamSpec((f,), ("ff",), init="zeros")
    if cfg.mlp_bias:
        t["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    return t


def _moe_tmpl(cfg: ModelConfig) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, E), ("embed", None)),
        "we_gate": ParamSpec((E, d, f), ("experts", "embed", None)),
        "we_up": ParamSpec((E, d, f), ("experts", "embed", None)),
        "we_down": ParamSpec((E, f, d), ("experts", None, "embed")),
    }


def _mamba_tmpl(cfg: ModelConfig) -> dict:
    d, di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ff")),
        "conv_w": ParamSpec((di, K), ("ff", None)),
        "conv_b": ParamSpec((di,), ("ff",), init="zeros"),
        "x_proj": ParamSpec((di, R + 2 * N), ("ff", None)),
        "dt_w": ParamSpec((R, di), (None, "ff")),
        "dt_b": ParamSpec((di,), ("ff",), init="zeros"),
        "A_log": ParamSpec((di, N), ("ff", None), init="ones"),
        "D": ParamSpec((di,), ("ff",), init="ones"),
        "out_proj": ParamSpec((di, d), ("ff", "embed")),
    }


def _rec_tmpl(cfg: ModelConfig) -> dict:
    d, w, K = cfg.d_model, cfg.lru_width, cfg.ssm_conv
    return {
        "wy": ParamSpec((d, w), ("embed", "ff")),
        "wx": ParamSpec((d, w), ("embed", "ff")),
        "conv_w": ParamSpec((w, K), ("ff", None)),
        "conv_b": ParamSpec((w,), ("ff",), init="zeros"),
        "w_a": ParamSpec((w, w), ("ff", None)),
        "w_x": ParamSpec((w, w), ("ff", None)),
        "lam": ParamSpec((w,), ("ff",), init="ones"),
        "out_w": ParamSpec((w, d), ("ff", "embed")),
    }


def _layer_tmpl(cfg: ModelConfig) -> dict:
    """One decoder layer for dense/moe families: nested sublayer subtrees."""
    t = {}
    t.update(_norm_tmpl(cfg, "ln1"))
    t.update(_norm_tmpl(cfg, "ln2"))
    t["attn"] = _attn_tmpl(cfg)
    t["mlp"] = _moe_tmpl(cfg) if cfg.family == "moe" else _mlp_tmpl(cfg)
    return t


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rows padded to a multiple of 256 so the embedding/lm-head shard
    evenly on any model axis up to 256 (Megatron-style vocab padding; the
    padded logits train to -inf and are never valid targets)."""
    return -(-cfg.vocab_size // 256) * 256


def build_template(cfg: ModelConfig) -> dict:
    V, d = padded_vocab(cfg), cfg.d_model
    base = {"embed": ParamSpec((V, d), ("vocab", "embed"), scale=0.01)}
    if not cfg.tie_embeddings:
        base["lm_head"] = ParamSpec((d, V), ("embed", "vocab"), scale=0.01)
    base.update(_norm_tmpl(cfg, "final_norm"))

    if cfg.family in ("dense", "moe"):
        base["layers"] = _stack(_layer_tmpl(cfg), cfg.n_layers)
    elif cfg.family == "ssm":
        lt = {}
        lt.update(_norm_tmpl(cfg, "ln1"))
        lt["mamba"] = _mamba_tmpl(cfg)
        base["layers"] = _stack(lt, cfg.n_layers)
    elif cfg.family == "hybrid":
        pattern = cfg.block_pattern
        kinds = [pattern[i % len(pattern)] for i in range(cfg.n_layers)]
        n_rec, n_attn = kinds.count("rec"), kinds.count("attn")
        rec = {}
        rec.update(_norm_tmpl(cfg, "ln1"))
        rec.update(_norm_tmpl(cfg, "ln2"))
        rec["rec"] = _rec_tmpl(cfg)
        rec["mlp"] = _mlp_tmpl(cfg)
        attn = {}
        attn.update(_norm_tmpl(cfg, "ln1"))
        attn.update(_norm_tmpl(cfg, "ln2"))
        attn["attn"] = _attn_tmpl(cfg)
        attn["mlp"] = _mlp_tmpl(cfg)
        base["rec_layers"] = _stack(rec, n_rec)
        base["attn_layers"] = _stack(attn, n_attn)
    elif cfg.family == "encdec":
        enc = {}
        enc.update(_norm_tmpl(cfg, "ln1"))
        enc.update(_norm_tmpl(cfg, "ln2"))
        enc["attn"] = _attn_tmpl(cfg)
        enc["mlp"] = _mlp_tmpl(cfg)
        dec = dict(enc)
        dec.update(_norm_tmpl(cfg, "lnc"))
        dec["cross"] = _attn_tmpl(cfg, cross=True)
        base["enc_layers"] = _stack(enc, cfg.enc_layers)
        base["dec_layers"] = _stack(dec, cfg.n_layers)
        base.update({f"enc_norm{k[10:]}": v for k, v in _norm_tmpl(cfg, "final_norm").items()})
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return base


def count_params_config(cfg: ModelConfig, active_only: bool = False) -> int:
    tmpl = build_template(cfg)
    total = param_count(tmpl)
    if active_only and cfg.family == "moe":
        expert_total = param_count(
            {k: v for k, v in tmpl["layers"]["mlp"].items() if k.startswith("we_")}
        )
        frac = cfg.experts_per_token / cfg.n_experts
        total -= int(expert_total * (1.0 - frac))
    return total


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.template = build_template(cfg)

    # -- params -------------------------------------------------------------

    def init_params(self, rng) -> dict:
        return init_from_template(self.template, rng, jnp.dtype(self.cfg.param_dtype))

    def abstract_params(self) -> dict:
        return abstract_from_template(self.template, jnp.dtype(self.cfg.param_dtype))

    def param_pspecs(self, rules: dict) -> dict:
        return pspecs_from_template(self.template, rules)

    # -- embedding / head ----------------------------------------------------

    def embed(self, params, tokens):
        dt = cfg_dtype(self.cfg)
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
        return constrain(x, "batch", "seq", "embed")

    def logits(self, params, h):
        cfg = self.cfg
        dt = cfg_dtype(cfg)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        # bf16 operands with f32 accumulation: halves the wire bytes of the
        # all-gather feeding the vocab-sharded head matmul (§Perf It2/It3)
        h = constrain(h.astype(dt), "batch", "seq", "embed")
        out = jnp.matmul(h, w.astype(dt), preferred_element_type=jnp.float32)
        return constrain(out, "batch", "inner_seq", "act_vocab")

    def _final_norm(self, params, h):
        from .layers import apply_norm

        return apply_norm(self.cfg.norm, h, params["final_norm"], params.get("final_norm_b"))

    # -- full-sequence forward -------------------------------------------------

    def hidden_states(self, params, batch, mesh_info=None, collect_cache=False):
        cfg = self.cfg
        dt = cfg_dtype(cfg)
        if cfg.family == "encdec":
            enc_out = forward_encoder(params, cfg, batch["frames"], mesh_info)
            B, S = batch["inputs"].shape
            pos = jnp.arange(S)[None, :]
            x = self.embed(params, batch["inputs"])
            x = x + sinusoidal_embedding(pos, cfg.d_model).astype(dt)
            h, extras = forward_decoder(
                params, cfg, x, pos, enc_out, mesh_info, collect_cache=collect_cache
            )
            return self._final_norm(params, h), (extras, enc_out)
        if cfg.embeds_input and "embeds" in batch:
            x = batch["embeds"].astype(dt)
            B, S = x.shape[:2]
        else:
            x = self.embed(params, batch["inputs"])
            B, S = batch["inputs"].shape
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            if cfg.rope == "mrope":
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        if cfg.family == "hybrid":
            h, extras = forward_hybrid(
                params, cfg, x, positions, mesh_info, collect_cache=collect_cache
            )
        else:
            h, extras = forward_stack(
                params, cfg, x, positions, mesh_info, collect_cache=collect_cache
            )
        return self._final_norm(params, h), extras

    # -- training loss -----------------------------------------------------------

    def loss_fn(self, params, batch, mesh_info=None):
        cfg = self.cfg
        h, _ = self.hidden_states(params, batch, mesh_info)
        targets = batch["targets"]
        if cfg.loss_chunk and cfg.loss_chunk < h.shape[1]:
            return self._chunked_loss(params, h, targets)
        logits = self.logits(params, h)
        return _ce_loss(logits, targets)

    def _chunked_loss(self, params, h, targets):
        cfg = self.cfg
        C = cfg.loss_chunk
        B, S, d = h.shape
        n = S // C
        hc = h[:, : n * C].reshape(B, n, C, d).transpose(1, 0, 2, 3)
        tc = targets[:, : n * C].reshape(B, n, C).transpose(1, 0, 2)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

        def body(acc, inp):
            hb, tb = inp
            logits = hb.astype(jnp.float32) @ w.astype(jnp.float32)
            return acc + _ce_loss(logits, tb) * tb.size, None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
        return total / (B * n * C)

    # -- serving -------------------------------------------------------------------

    def prefill(self, params, batch, mesh_info=None):
        """Full forward; returns (last-token logits, decode cache)."""
        cfg = self.cfg
        h, extras = self.hidden_states(params, batch, mesh_info, collect_cache=True)
        logits = self.logits(params, h[:, -1:, :])[..., : cfg.vocab_size]
        cache = self._assemble_cache(batch, extras)
        return logits, cache

    def _assemble_cache(self, batch, extras):
        cfg = self.cfg
        kvdt = self.kv_dtype()
        if cfg.family in ("dense", "moe"):
            k, v = extras
            return {"k": k.astype(kvdt), "v": v.astype(kvdt)}
        if cfg.family == "ssm":
            conv, ssm = extras
            return {"conv": conv, "ssm": ssm}
        if cfg.family == "hybrid":
            (rec_extras, attn_extras) = extras
            conv, rec = rec_extras
            k, v = attn_extras
            W = cfg.local_window
            # keep the last W positions; decode continues the ring at pos % W,
            # so position p must sit at slot p % W — roll the slice to align.
            S = k.shape[2]
            if S > W:
                k = jnp.roll(k[:, :, -W:], shift=S % W, axis=2)
                v = jnp.roll(v[:, :, -W:], shift=S % W, axis=2)
            return {"conv": conv, "rec": rec, "k": k.astype(kvdt), "v": v.astype(kvdt)}
        if cfg.family == "encdec":
            dec_extras, _enc_out = extras
            self_kv, cross_kv = dec_extras
            k, v = self_kv
            ck, cv = cross_kv
            return {
                "k": k.astype(kvdt),
                "v": v.astype(kvdt),
                "cross_k": ck.astype(kvdt),
                "cross_v": cv.astype(kvdt),
            }
        raise ValueError(cfg.family)

    def decode_step(self, params, cache, tokens, pos, mesh_info=None):
        """One decode step. tokens [B, 1] int32; pos: scalar position."""
        cfg = self.cfg
        x = self.embed(params, tokens)
        if cfg.family == "encdec":  # absolute positions (whisper)
            posarr = jnp.full((1, 1), pos, jnp.int32)
            x = x + sinusoidal_embedding(posarr, cfg.d_model).astype(x.dtype)
        if cfg.family in ("dense", "moe"):
            h, cache = decode_stack(params, cfg, x, cache, pos, mesh_info)
        elif cfg.family == "ssm":
            h, cache = decode_ssm(params, cfg, x, cache, mesh_info)
        elif cfg.family == "hybrid":
            h, cache = decode_hybrid(params, cfg, x, cache, pos, mesh_info)
        elif cfg.family == "encdec":
            h, cache = decode_encdec(params, cfg, x, cache, pos, mesh_info)
        else:
            raise ValueError(cfg.family)
        h = self._final_norm(params, h)
        return self.logits(params, h)[..., : cfg.vocab_size], cache

    # -- cache templates (for the decode dry-run input specs) -------------------

    def kv_dtype(self):
        cfg = self.cfg
        return jnp.dtype(cfg.kv_cache_dtype or cfg.compute_dtype)

    def abstract_cache(self, batch_size: int, seq_len: int) -> dict:
        cfg = self.cfg
        kvdt = self.kv_dtype()  # k/v caches (may be quantized, e.g. fp8)
        cdt = jnp.dtype(cfg.compute_dtype)  # conv tails / recurrent states
        L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
        if cfg.family in ("dense", "moe"):
            shp = (L, batch_size, seq_len, KV, hd)
            return {"k": jax.ShapeDtypeStruct(shp, kvdt), "v": jax.ShapeDtypeStruct(shp, kvdt)}
        if cfg.family == "ssm":
            return {
                "conv": jax.ShapeDtypeStruct(
                    (L, batch_size, cfg.ssm_conv - 1, cfg.d_inner), cdt
                ),
                "ssm": jax.ShapeDtypeStruct(
                    (L, batch_size, cfg.d_inner, cfg.ssm_state), jnp.float32
                ),
            }
        if cfg.family == "hybrid":
            kinds = [cfg.block_pattern[i % len(cfg.block_pattern)] for i in range(L)]
            n_rec, n_attn = kinds.count("rec"), kinds.count("attn")
            W = min(cfg.local_window, seq_len)
            return {
                "conv": jax.ShapeDtypeStruct(
                    (n_rec, batch_size, cfg.ssm_conv - 1, cfg.lru_width), cdt
                ),
                "rec": jax.ShapeDtypeStruct((n_rec, batch_size, cfg.lru_width), jnp.float32),
                "k": jax.ShapeDtypeStruct((n_attn, batch_size, W, KV, hd), kvdt),
                "v": jax.ShapeDtypeStruct((n_attn, batch_size, W, KV, hd), kvdt),
            }
        if cfg.family == "encdec":
            shp = (L, batch_size, seq_len, KV, hd)
            cshp = (L, batch_size, cfg.enc_positions, KV, hd)
            return {
                "k": jax.ShapeDtypeStruct(shp, kvdt),
                "v": jax.ShapeDtypeStruct(shp, kvdt),
                "cross_k": jax.ShapeDtypeStruct(cshp, kvdt),
                "cross_v": jax.ShapeDtypeStruct(cshp, kvdt),
            }
        raise ValueError(cfg.family)


def _ce_loss(logits, targets):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
