"""Mamba-1 block (falcon-mamba-7b): depthwise causal conv + selective scan.

Train/prefill run the recurrence with ``lax.scan`` over the sequence (the
Pallas ``mamba_scan`` kernel replaces this hot loop on TPU; ``kernels/
mamba_scan/ref.py`` is this exact recurrence).  Decode is a single recurrence
step carrying (conv_state, ssm_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import constrain


def depthwise_causal_conv(x, w, b, state=None):
    """x [B, S, C], w [C, K] depthwise causal conv.

    If ``state`` [B, K-1, C] is given (decode), it is the running tail of
    previous inputs; returns (y, new_state)."""
    B, S, C = x.shape
    K = w.shape[1]
    if state is None:
        ctx = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(ctx[:, j : j + S, :] * w[:, j].astype(x.dtype) for j in range(K))
    if b is not None:
        y = y + b.astype(x.dtype)
    new_state = ctx[:, -(K - 1) :, :] if K > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y, new_state


def selective_scan(u, dt, A, B_ssm, C_ssm, D, h0=None):
    """The mamba1 SSM recurrence.

    u      [B, S, C]   (post-conv activations)
    dt     [B, S, C]   (softplus'd step sizes)
    A      [C, N]      (negative; A = -exp(A_log))
    B_ssm  [B, S, N]
    C_ssm  [B, S, N]
    D      [C]
    h0     [B, C, N] initial state (decode) or None

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) outer B_t
    y_t = (h_t . C_t) + D * u_t
    returns (y [B, S, C], h_final [B, C, N])
    """
    Bsz, S, C = u.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, C, N), jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, inp):
        # the discretized dA_t/dBu_t are computed per step: materializing
        # them for the whole sequence would be an O(B*S*C*N) tensor
        # (tens of GB per device at d_inner=8192)
        dt_t, dtu_t, B_t, C_t = inp  # [B,C], [B,C], [B,N], [B,N]
        dA_t = jnp.exp(dt_t[..., None] * Af)  # [B,C,N]
        h = dA_t * h + dtu_t[..., None] * B_t[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, C_t)
        return h, y

    xs = (
        dt.astype(jnp.float32).transpose(1, 0, 2),
        (dt * u).astype(jnp.float32).transpose(1, 0, 2),
        B_ssm.astype(jnp.float32).transpose(1, 0, 2),
        C_ssm.astype(jnp.float32).transpose(1, 0, 2),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + D.astype(jnp.float32) * u.astype(jnp.float32)
    return y.astype(u.dtype), h


def mamba_block(x, p, cfg, compute_dtype, conv_state=None, ssm_state=None):
    """Full mamba1 mixer. x [B, S, d] -> (y [B, S, d], new conv/ssm states)."""
    cast = lambda w: w.astype(compute_dtype)
    di = cfg.d_inner
    xz = x @ cast(p["in_proj"])  # [B, S, 2*di]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", "inner_seq", "act_ff")
    x_conv, new_conv = depthwise_causal_conv(x_in, p["conv_w"], p.get("conv_b"), conv_state)
    u = jax.nn.silu(x_conv)
    proj = u @ cast(p["x_proj"])  # [B, S, R + 2N]
    R, N = cfg.dt_rank, cfg.ssm_state
    dt_raw, B_ssm, C_ssm = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_raw @ cast(p["dt_w"]) + cast(p["dt_b"]))  # [B, S, di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = selective_scan(u, dt, A, B_ssm, C_ssm, p["D"], h0=ssm_state)
    y = y * jax.nn.silu(z)
    out = y @ cast(p["out_proj"])
    return out, new_conv, h
