"""RecurrentGemma building blocks: the RG-LRU recurrent (temporal-mix) block.

RG-LRU recurrence (Griffin / RecurrentGemma, arXiv:2402.19427):

  r_t = sigmoid(W_a x_t)                       (recurrence gate)
  i_t = sigmoid(W_x x_t)                       (input gate)
  log a_t = -c * softplus(Lambda) * r_t        (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The published model uses block-diagonal gate matrices; we use dense
[lru, lru] gates (recorded in DESIGN.md as a simplification that slightly
*increases* parameter count and FLOPs — conservative for roofline claims).
The Pallas ``rglru_scan`` kernel replaces the lax.scan on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import constrain
from .ssm import depthwise_causal_conv

_C = 8.0


def rglru_scan(x, r, i, lam, h0=None):
    """x, r, i: [B, S, W]; lam: [W]. Returns (y [B,S,W], h_final [B,W])."""
    B, S, W = x.shape
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i * x).astype(jnp.float32) * jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    (h, ys) = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(x.dtype), h


def recurrent_block(x, p, cfg, compute_dtype, conv_state=None, rec_state=None):
    """RecurrentGemma temporal-mix block.

    x [B, S, d] -> (out [B, S, d], new_conv_state, new_rec_state)."""
    cast = lambda w: w.astype(compute_dtype)
    # y branch: linear + GELU
    y_branch = jax.nn.gelu(x @ cast(p["wy"]))
    # x branch: linear -> causal conv -> RG-LRU
    xb = x @ cast(p["wx"])
    xb = constrain(xb, "batch", "inner_seq", "act_ff")
    xb, new_conv = depthwise_causal_conv(xb, p["conv_w"], p.get("conv_b"), conv_state)
    r = jax.nn.sigmoid(xb @ cast(p["w_a"]))
    i = jax.nn.sigmoid(xb @ cast(p["w_x"]))
    lru, new_rec = rglru_scan(xb, r, i, p["lam"], h0=rec_state)
    out = (lru * y_branch) @ cast(p["out_w"])
    return out, new_conv, new_rec
