"""Model substrate: layers, families (dense / MoE / SSM / hybrid / enc-dec),
parameter templates, KV caches and step functions."""
