"""Core layers: norms, rotary-embedding variants, MLPs, and grouped-query
attention with three implementations:

  * ``naive``   — materializes the [.., S_q, S_k] score matrix;
  * ``chunked`` — online-softmax over KV chunks (flash-attention algorithm in
                  pure jnp; bounded memory, what the dry-run lowers for long
                  sequences);
  * decode      — one-token query against a static-shape KV cache with a
                  position mask.

All matmuls run in the config's compute dtype (bf16 by default); softmax and
norms accumulate in fp32.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .common import constrain

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(kind: str, x, scale, bias=None):
    if kind == "rmsnorm":
        return rmsnorm(x, scale)
    return layernorm(x, scale, bias)


# ---------------------------------------------------------------------------
# Rotary position embeddings (default / half / mrope / none / sinusoidal)
# ---------------------------------------------------------------------------


def _rope_angles(positions, dim: int, theta: float):
    """positions [...], returns cos/sin of shape [..., dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """x [..., dim] with interleaved halves convention: split in two halves."""
    d = x.shape[-1] // 2
    x1, x2 = x[..., :d], x[..., d:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_rope(kind: str, x, positions, theta: float):
    """x: [B, S, H, hd]; positions: [B, S] (or [3, B, S] for mrope)."""
    if kind in ("none", "sinusoidal"):
        return x
    hd = x.shape[-1]
    if kind == "default":
        cos, sin = _rope_angles(positions, hd, theta)  # [B, S, hd/2]
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        return _rotate(x, cos, sin)
    if kind == "half":
        # rotate only the first half of the head dim (ChatGLM 2d / partial)
        rot, keep = x[..., : hd // 2], x[..., hd // 2 :]
        cos, sin = _rope_angles(positions, hd // 2, theta)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
        return jnp.concatenate([_rotate(rot, cos, sin), keep], axis=-1)
    if kind == "mrope":
        # Multimodal rope (qwen2-vl): the head dim is split into (t, h, w)
        # sections, each rotated by its own position stream.
        # positions: [3, B, S]
        half = hd // 2
        sec = _mrope_sections(half)
        cos_parts, sin_parts = [], []
        for i, width in enumerate(sec):
            c, s = _rope_angles(positions[i], 2 * width, theta)
            cos_parts.append(c)
            sin_parts.append(s)
        cos = jnp.concatenate(cos_parts, axis=-1)[:, :, None, :]  # [B,S,1,half]
        sin = jnp.concatenate(sin_parts, axis=-1)[:, :, None, :]
        return _rotate(x, cos, sin)
    raise ValueError(f"unknown rope kind {kind}")


def _mrope_sections(half: int) -> tuple[int, int, int]:
    """(t, h, w) frequency sections; qwen2-vl uses (16, 24, 24) for hd=128."""
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


def sinusoidal_embedding(positions, d_model: int):
    """Absolute sinusoidal position embeddings [..., d_model]."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(1, half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_apply(kind: str, x, p, compute_dtype):
    """p: dict with wi_gate/wi_up/wo (gated) or wi/wo (plain)."""
    cast = lambda w: w.astype(compute_dtype)
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        g = x @ cast(p["wi_gate"])
        u = x @ cast(p["wi_up"])
        h = act(g) * u
    elif kind == "gelu":
        h = jax.nn.gelu(x @ cast(p["wi"]) + (cast(p["bi"]) if "bi" in p else 0))
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(x @ cast(p["wi"])))
    else:
        raise ValueError(f"unknown mlp kind {kind}")
    h = constrain(h, "batch", "inner_seq", "act_ff")
    out = h @ cast(p["wo"])
    if "bo" in p:
        out = out + cast(p["bo"])
    return out


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _split_heads(x, n_heads: int, head_dim: int):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def gqa_attention(
    q,  # [B, Sq, H, hd]
    k,  # [B, Sk, KV, hd]
    v,  # [B, Sk, KV, hd]
    *,
    causal: bool,
    impl: str = "chunked",
    chunk: int = 1024,
    q_offset: int = 0,
    local_window: int = 0,
    kv_len: Optional[jax.Array] = None,  # decode: number of valid kv slots
):
    """Grouped-query attention.  ``q_offset`` positions the queries within
    the kv sequence (prefill chunking / decode).  ``local_window`` > 0 adds a
    sliding-window constraint.  ``kv_len`` masks cache slots >= kv_len."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / (hd**0.5)

    if (
        impl == "pallas"
        and Sq > 1
        and kv_len is None
        and local_window == 0
        and Sq % 128 == 0
        and k.shape[1] % 128 == 0
    ):
        # the Pallas flash kernel: scores/probs never touch HBM.  The cost
        # model prices the pallas_call from its operands (the kernel's true
        # HBM traffic); TPU executes the kernel, CPU tests run interpret.
        from repro.kernels.ops import flash_attention_trainable as _flash

        return _flash(q, k, v, causal, q_offset)

    q5 = q.reshape(B, Sq, KV, G, hd)

    if impl in ("naive",) or Sq == 1:
        return _attn_naive(q5, k, v, scale, causal, q_offset, local_window, kv_len).reshape(
            B, Sq, H, hd
        )
    return _attn_chunked(q5, k, v, scale, causal, q_offset, local_window, kv_len, chunk).reshape(
        B, Sq, H, hd
    )


def _mask(Sq, Sk, q_offset, causal, local_window, kv_len, k_offset=0):
    qpos = q_offset + jnp.arange(Sq)[:, None]  # [Sq, 1]
    kpos = k_offset + jnp.arange(Sk)[None, :]  # [1, Sk]
    m = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        m &= kpos <= qpos
    if local_window:
        m &= kpos > qpos - local_window
    if kv_len is not None:
        m &= kpos < kv_len
    return m


def _attn_naive(q5, k, v, scale, causal, q_offset, local_window, kv_len):
    B, Sq, KV, G, hd = q5.shape
    Sk = k.shape[1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q5, k, preferred_element_type=jnp.float32) * scale
    mask = _mask(Sq, Sk, q_offset, causal, local_window, kv_len)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q5.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def _attn_chunked(q5, k, v, scale, causal, q_offset, local_window, kv_len, chunk):
    """Online-softmax over KV chunks (the flash-attention recurrence)."""
    B, Sq, KV, G, hd = q5.shape
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", q5, kb, preferred_element_type=jnp.float32) * scale
        mask = _mask(
            Sq,
            chunk,
            q_offset,
            causal,
            local_window,
            jnp.minimum(Sk, kv_len) if kv_len is not None else Sk,
            k_offset=idx * chunk,
        )
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), q5.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4)  # [B, Sq, KV, G, hd]


def qkv_project(x, p, cfg, compute_dtype):
    """x [B,S,d] -> q [B,S,H,hd], k/v [B,S,KV,hd]."""
    cast = lambda w: w.astype(compute_dtype)
    q = x @ cast(p["wq"])
    k = x @ cast(p["wk"])
    v = x @ cast(p["wv"])
    if cfg.qkv_bias:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    q = _split_heads(q, cfg.n_heads, cfg.head_dim)
    k = _split_heads(k, cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(v, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    return q, k, v


def attn_output(o, p, cfg, compute_dtype):
    B, S, H, hd = o.shape
    out = o.reshape(B, S, H * hd) @ p["wo"].astype(compute_dtype)
    if cfg.attn_out_bias and "bo" in p:
        out = out + p["bo"].astype(compute_dtype)
    return out
