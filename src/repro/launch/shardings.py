"""Logical-axis sharding rules per (config × shape-kind × mesh).

The parallelism recipe:

  * ``train`` / ``prefill``: DP over (pod, data); Megatron-style TP over
    ``model`` (attention head dims, MLP hidden, vocab/embedding); EP for MoE
    experts over ``model`` (shard_map path with a psum combine); sequence
    stays unsharded (the chunked-attention scan bounds activation memory).
  * ``decode``: batch over (pod, data); the KV cache is sequence-sharded
    over ``model`` — attention contracts head_dim locally and reduces the
    tiny softmax statistics across ``model`` (flash-decode in SPMD form).
  * ``long`` (batch=1 decode): no batch to shard — recurrent/conv states and
    window caches are sharded over every axis (data and model).

Activation head-count constraints are applied only when the head count
divides the axis (otherwise left to propagation); flattened weight dims
(H*hd etc.) always divide the 16-way model axis for the assigned archs.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from .mesh import data_axes


def logical_rules(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    dp = data_axes(mesh)
    dp_entry = dp if len(dp) > 1 else dp[0]
    n_model = mesh.shape["model"]
    long_ctx = shape.kind == "decode" and shape.global_batch < mesh.shape[dp[0]]

    if cfg.parallelism == "fsdp" and shape.kind in ("train", "prefill"):
        return _fsdp_rules(cfg, shape, mesh, dp)
    if cfg.parallelism == "fsdp_ep" and shape.kind in ("train", "prefill"):
        # MoE hybrid: experts stay expert-parallel over `model` (tokens move,
        # not banks); dense weights are fully sharded over (data, model) and
        # gathered per layer; batch shards over data only so the EP psum
        # combine applies.
        rules = _fsdp_rules(cfg, shape, mesh, dp)
        rules["batch"] = dp if len(dp) > 1 else dp[0]
        rules["experts"] = "model"
        return rules
    if cfg.parallelism == "ep_a2a" and shape.kind in ("train", "prefill"):
        # full EP: tokens sharded over every axis, local scatter dispatch,
        # all-to-all token exchange with the expert shards over `model`.
        rules = _fsdp_rules(cfg, shape, mesh, dp)
        rules["experts"] = "model"
        return rules

    seq_rule = None
    if (
        cfg.sequence_parallel
        and shape.kind in ("train", "prefill")
        and shape.seq_len % n_model == 0
    ):
        seq_rule = "model"  # sequence parallelism (Megatron SP)
    rules = {
        "batch": dp_entry,
        "seq": seq_rule,
        "embed": None,
        "layers": None,
        # weight dims (flattened head dims — always divisible)
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        # activation dims (only when they divide the axis)
        "act_heads": "model" if cfg.n_heads % n_model == 0 else None,
        "act_kv": "model" if cfg.n_kv_heads % n_model == 0 else None,
        "act_ff": "model",
        "act_vocab": "model",
        "inner_seq": None,
        # decode cache axes
        "cache_seq": "model" if shape.kind == "decode" else None,
        "state": None,
    }
    if long_ctx:
        # batch=1: spread states/caches over everything available
        rules["batch"] = None
        rules["ff"] = dp + ("model",)
        rules["cache_seq"] = dp_entry
        rules["act_heads"] = None
        rules["act_kv"] = None
    return rules


def _fsdp_rules(cfg: ModelConfig, shape: ShapeConfig, mesh, dp: tuple) -> dict:
    """Fully-sharded data parallelism: the batch spreads over every mesh
    axis; weight matrices shard over (data..., model) on their wide dims and
    GSPMD gathers them per layer (collective volume ~ weights, independent
    of the batch).  Falls back to model-only sharding on dims that the full
    axis product does not divide."""
    all_axes = dp + ("model",)
    n_all = mesh.size

    def wide(dim_size: int):
        if dim_size % n_all == 0:
            return all_axes
        return "model" if dim_size % mesh.shape["model"] == 0 else None

    from repro.models.model import padded_vocab

    batch_ok = shape.global_batch % n_all == 0
    return {
        "batch": all_axes if batch_ok else (dp if len(dp) > 1 else dp[0]),
        "seq": None,
        "embed": None,
        "layers": None,
        "heads": wide(cfg.q_dim),
        "kv_heads": wide(cfg.kv_dim),
        "ff": wide(max(cfg.d_ff, cfg.d_inner if cfg.family == "ssm" else 0,
                       cfg.lru_width if cfg.family == "hybrid" else 0) or 1),
        "vocab": wide(padded_vocab(cfg)),
        "experts": "model",
        "act_heads": None,
        "act_kv": None,
        "act_ff": None,
        "act_vocab": None,
        "inner_seq": None,
        "cache_seq": None,
        "state": None,
    }


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """PartitionSpecs for the input batch pytree (follows the 'batch' rule,
    so TP/FSDP/long-context layouts stay consistent)."""
    dp_entry = logical_rules(cfg, shape, mesh)["batch"]
    specs = {"inputs": P(dp_entry, None)}
    if shape.kind == "train":
        specs["targets"] = P(dp_entry, None)
    if cfg.embeds_input:
        specs["embeds"] = P(dp_entry, None, None)
        if cfg.rope == "mrope":
            specs["positions"] = P(None, dp_entry, None)
    if cfg.family == "encdec":
        specs["frames"] = P(dp_entry, None, None)
    return specs


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    """PartitionSpecs for the decode cache pytree (mirrors
    Model.abstract_cache structure)."""
    rules = logical_rules(cfg, shape, mesh)
    b = rules["batch"]
    cseq = rules["cache_seq"]
    kvh = rules["act_kv"]
    ff = rules["ff"]
    if cfg.family in ("dense", "moe"):
        kv = P(None, b, cseq, kvh, None)
        return {"k": kv, "v": kv}
    if cfg.family == "ssm":
        return {
            "conv": P(None, b, None, ff),
            "ssm": P(None, b, ff, None),
        }
    if cfg.family == "hybrid":
        return {
            "conv": P(None, b, None, ff),
            "rec": P(None, b, ff),
            "k": P(None, b, cseq, kvh, None),
            "v": P(None, b, cseq, kvh, None),
        }
    if cfg.family == "encdec":
        kv = P(None, b, cseq, kvh, None)
        ckv = P(None, b, None, kvh, None)
        return {"k": kv, "v": kv, "cross_k": ckv, "cross_v": ckv}
    raise ValueError(cfg.family)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
