"""Step functions (train / prefill / decode) and their abstract input specs
— the single place the dry-run, the trainer and the server build jitted
steps from.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model
from repro.optim import AdamW, warmup_cosine

from .mesh import data_axes


def mesh_info_for(cfg: ModelConfig, mesh) -> Optional[tuple]:
    """(mesh, data_axes, model_axis) for the MoE expert-parallel path.

    Under FSDP the expert banks are gathered per layer like every other
    weight and routing runs device-local (model_axis=None selects the
    shard_map fsdp-local path in moe_apply)."""
    if mesh is None or cfg.family != "moe":
        return None
    dp = data_axes(mesh)
    if cfg.parallelism == "fsdp":
        return (mesh, dp + ("model",), None)
    if cfg.parallelism == "ep_a2a":
        return (mesh, dp + ("model",), "model", "ep_a2a")
    # "tp" and "fsdp_ep": expert parallelism over `model`, batch over data
    return (mesh, dp if len(dp) > 1 else dp[0], "model")


def make_optimizer(total_steps: int = 10_000) -> AdamW:
    warmup = max(1, min(200, total_steps // 10))
    return AdamW(learning_rate=warmup_cosine(3e-4, warmup, total_steps))


def make_train_step(cfg: ModelConfig, mesh=None, optimizer: Optional[AdamW] = None):
    """Returns (model, optimizer, train_step(params, opt_state, batch))."""
    model = Model(cfg)
    opt = optimizer or make_optimizer()
    minfo = mesh_info_for(cfg, mesh)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, batch, minfo))(params)
        params, opt_state, metrics = opt.update(grads, opt_state, params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return model, opt, train_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    model = Model(cfg)
    minfo = mesh_info_for(cfg, mesh)

    def prefill_step(params, batch):
        return model.prefill(params, batch, minfo)

    return model, prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None):
    model = Model(cfg)
    minfo = mesh_info_for(cfg, mesh)

    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, minfo)

    return model, decode_step


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStructs — no allocation), per shape kind
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract batch for train/prefill; for decode, the abstract
    (cache, tokens, pos) triple is provided by ``decode_input_specs``."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    batch = {"inputs": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "train":
        batch["targets"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.embeds_input:
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), f32)
        if cfg.rope == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_positions, cfg.d_model), f32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """(cache, tokens, pos) abstract inputs for one serve_step: one new token
    against a KV cache of seq_len."""
    model = Model(cfg)
    B, S = shape.global_batch, shape.seq_len
    cache = model.abstract_cache(B, S)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, pos


def concrete_batch(cfg: ModelConfig, shape_or_bs, seq_len: Optional[int] = None, rng=None):
    """Small concrete batch for tests/examples (deterministic)."""
    if isinstance(shape_or_bs, ShapeConfig):
        B, S = shape_or_bs.global_batch, shape_or_bs.seq_len
    else:
        B, S = shape_or_bs, seq_len
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    batch = {
        "inputs": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.embeds_input:
        batch["embeds"] = 0.02 * jax.random.normal(k3, (B, S, cfg.d_model), jnp.float32)
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            k3, (B, cfg.enc_positions, cfg.d_model), jnp.float32
        )
    return batch
