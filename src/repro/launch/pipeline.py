"""Optional pipeline parallelism (GPipe-style) via shard_map + ppermute.

Stages hold disjoint slices of the layer stack (in_specs shard the stacked
layer params over the ``stage`` mesh axis); microbatches flow through the
classic looped schedule: every tick each stage processes one activation and
collective-permutes it downstream.  Bubble fraction = (S-1)/(M+S-1).

This is a first-class feature for deployments where the model axis alone
cannot hold the layer stack; the 40-cell dry-run matrix uses DP x TP (+ pod
DP), and PP is validated separately (tests/test_pipeline.py) on small
meshes, as recorded in DESIGN.md §5.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import shard_map


def gpipe(
    stage_fn: Callable,
    mesh,
    axis: str = "stage",
):
    """Builds ``run(stage_params, microbatches) -> outputs``.

    stage_fn(lp, x) applies one stage's layer slice to activation x.
    stage_params: pytree with leading dim == n_stages (sharded over axis).
    microbatches: [M, mb, ...] (replicated input; stage 0 injects them).
    Returns outputs [M, mb, ...] (replicated).
    """
    n_stages = mesh.shape[axis]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(stage_params, xs):
        stage = jax.lax.axis_index(axis)
        sp = jax.tree.map(lambda a: a[0], stage_params)  # this stage's slice
        M = xs.shape[0]
        T = M + n_stages - 1
        state = jnp.zeros_like(xs[0])
        outputs = jnp.zeros_like(xs)

        def tick(t, carry):
            state, outputs = carry
            # stage 0 injects microbatch t (while available); others consume
            # the permuted activation from upstream
            inject = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(stage == 0, xs[inject], state)
            y = stage_fn(sp, x_in)
            # the last stage emits microbatch (t - (S-1)) at tick t
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            emit = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(emit, y, outputs[out_idx]),
                out_idx,
                0,
            )
            state = jax.lax.ppermute(y, axis, perm)
            return (state, outputs)

        state, outputs = jax.lax.fori_loop(0, T, tick, (state, outputs))
        # replicate the last stage's outputs everywhere
        outputs = jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis)

    def run(stage_params, microbatches):
        specs_params = jax.tree.map(lambda _: P(axis), stage_params)
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(specs_params, P()),
            out_specs=P(),
        )(stage_params, microbatches)

    return run
