"""Serving driver: batched prefill + decode loop with the CAPre access plan
wired in (the plan is printed/exported so operators can see exactly what the
step will touch — the paper's prefetching hints for the tensor store).

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_4b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.core.access_plan import build_access_plan
from repro.launch.steps import concrete_batch, make_decode_step, make_prefill_step


class Server:
    def __init__(self, cfg, mesh=None, max_len: int = 256):
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.model, self.prefill_fn = make_prefill_step(cfg, mesh)
        _, self.decode_fn = make_decode_step(cfg, mesh)
        self._jit_prefill = jax.jit(self.prefill_fn)
        self._jit_decode = jax.jit(self.decode_fn, donate_argnums=(1,))

    def plan(self, batch_size: int):
        """The CAPre access plan of one decode step (compile-time, no
        allocation)."""
        return build_access_plan(
            lambda p, c, t: self.decode_fn(p, c, t, 0),
            self.model.abstract_params(),
            self.model.abstract_cache(batch_size, self.max_len),
            jax.ShapeDtypeStruct((batch_size, 1), jnp.int32),
        )

    def generate(self, params, batch: dict, steps: int, greedy: bool = True):
        """Prefill the prompt batch, then decode ``steps`` tokens."""
        B, S = batch["inputs"].shape
        # pad the cache to max_len so decode steps have static shapes
        pad = self.max_len - S
        if pad > 0 and self.cfg.family in ("dense", "moe", "encdec"):
            pass  # cache padding handled below via prefill on padded inputs
        logits, cache = self._jit_prefill(params, batch)
        cache = self._pad_cache(cache, S)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [tok]
        for i in range(steps - 1):
            logits, cache = self._jit_decode(params, cache, tok, S + i)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        return jnp.concatenate(out, axis=1)

    def _pad_cache(self, cache: dict, cur_len: int) -> dict:
        """Grow seq-dim cache buffers to max_len (static decode shapes)."""
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "encdec"):
            pad = self.max_len - cache["k"].shape[2]
            if pad > 0:
                for key in ("k", "v"):
                    c = cache[key]
                    cache[key] = jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    server = Server(cfg, max_len=args.prompt_len + args.gen)
    plan = server.plan(args.batch)
    print(f"access plan: {len(plan.records)} records, "
          f"{len(plan.collections())} collections, {plan.total_bytes/1e6:.1f} MB")
    for h in plan.hints()[:8]:
        print("  hint:", h)

    model = server.model
    params = model.init_params(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, args.batch, args.prompt_len)
    batch.pop("targets", None)
    t0 = time.perf_counter()
    tokens = server.generate(params, batch, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {tokens.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", tokens[0, :12].tolist())


if __name__ == "__main__":
    main()
