"""Training driver: config -> mesh -> sharded params/optimizer -> data
pipeline -> jitted train step -> checkpointed loop with fault-tolerance
hooks.

Usage (CPU-scale example; the same driver lowers onto the production mesh):

  PYTHONPATH=src python -m repro.launch.train --arch chatglm3_6b --smoke \
      --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data import DataPipeline, SyntheticLMSource
from repro.models.common import activate_sharding
from repro.runtime.fault import StragglerDetector

from .mesh import data_axes
from .shardings import batch_pspecs, logical_rules, named
from .steps import make_optimizer, make_train_step


class Trainer:
    def __init__(
        self,
        cfg,
        mesh=None,
        global_batch: int = 8,
        seq_len: int = 128,
        ckpt_dir: Optional[str] = None,
        total_steps: int = 1000,
        log_every: int = 10,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.shape = ShapeConfig("train", "train", seq_len, global_batch)
        self.model, self.opt, self.step_fn = make_train_step(
            cfg, mesh, make_optimizer(total_steps)
        )
        self.ckpt = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
        self.log_every = log_every
        self.stragglers = StragglerDetector()
        self.rules = logical_rules(cfg, self.shape, mesh) if mesh else {}

    # -- state --------------------------------------------------------------

    def init_state(self, seed: int = 0):
        params = self.model.init_params(jax.random.PRNGKey(seed))
        opt_state = self.opt.init(params)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            psh = named(self.mesh, self.model.param_pspecs(self.rules))
            params = jax.device_put(params, psh)
            opt_state = jax.device_put(
                opt_state,
                {"mu": psh, "nu": psh, "step": NamedSharding(self.mesh, P())},
            )
        return params, opt_state

    def maybe_restore(self, params, opt_state):
        start = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            abstract = {
                "params": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                "opt": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), opt_state),
            }
            start, state = self.ckpt.restore(like=abstract)
            params, opt_state = state["params"], state["opt"]
        return start, params, opt_state

    # -- loop ---------------------------------------------------------------

    def train(self, total_steps: int, seed: int = 0, save_every: int = 100):
        cfg = self.cfg
        params, opt_state = self.init_state(seed)
        start, params, opt_state = self.maybe_restore(params, opt_state)

        source = SyntheticLMSource(
            cfg.vocab_size, self.shape.global_batch, self.shape.seq_len, seed=seed,
            embeds_dim=cfg.d_model if cfg.embeds_input else 0,
            frames=cfg.enc_positions if cfg.family == "encdec" else 0,
            mrope=cfg.rope == "mrope",
        )
        if cfg.family == "encdec":
            source.embeds_dim = cfg.d_model
        pipeline = DataPipeline(source, start_step=start, prefetch=2)

        put = None
        if self.mesh is not None:
            bsh = named(self.mesh, batch_pspecs(cfg, self.shape, self.mesh))
            put = lambda b: jax.device_put(b, bsh)

        losses = []
        jit_step = jax.jit(self.step_fn, donate_argnums=(0, 1))
        try:
            with activate_sharding(self.mesh, self.rules) if self.mesh else _null():
                for step, batch in pipeline:
                    if step >= total_steps:
                        break
                    if put:
                        batch = put(batch)
                    t0 = time.perf_counter()
                    params, opt_state, metrics = jit_step(params, opt_state, batch)
                    loss = float(metrics["loss"])
                    dt = time.perf_counter() - t0
                    self.stragglers.record("self", dt)
                    losses.append(loss)
                    if step % self.log_every == 0:
                        tok_s = self.shape.global_batch * self.shape.seq_len / dt
                        print(f"step {step:5d} loss {loss:.4f} {dt*1e3:7.1f} ms/step {tok_s:,.0f} tok/s", flush=True)
                    if self.ckpt and step and step % save_every == 0:
                        self.ckpt.save(step, {"params": params, "opt": opt_state})
        finally:
            pipeline.close()
            if self.ckpt:
                self.ckpt.wait()
        return params, opt_state, losses


class _null:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=100)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    trainer = Trainer(
        cfg, mesh=None, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, total_steps=args.steps,
    )
    _, _, losses = trainer.train(args.steps, save_every=args.save_every)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f} over {len(losses)} steps)")


if __name__ == "__main__":
    main()
