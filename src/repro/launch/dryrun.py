import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes (single-pod 16x16 = 256 chips; multi-pod
2x16x16 = 512 chips), proving the distribution config is coherent, and
record the roofline inputs per cell:

  * ``compiled.memory_analysis()``  — proves the step fits per-device HBM
  * loop-aware jaxpr FLOPs/bytes    — launch/costmodel.py (XLA's own
    cost_analysis does not scale while bodies by trip count; we record both)
  * per-device collective bytes     — parsed from the partitioned HLO with
    while-trip multiplication (launch/hlo_parse.py)

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and are
consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch granite_moe_1b_a400m --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 2]
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _abstract_f32(tree):
    import jax

    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, "float32"), tree)


def run_cell(arch: str, shape_name: str, mesh_kind: str, overrides: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES, get_config, runnable_shapes
    from repro.models.common import activate_sharding
    from repro.models.model import Model
    from .costmodel import step_cost
    from .hlo_parse import collective_bytes
    from .mesh import make_production_mesh
    from .shardings import batch_pspecs, cache_pspecs, logical_rules, named
    from .steps import decode_input_specs, input_specs, make_decode_step, make_prefill_step, make_train_step

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "status": "running",
        "overrides": dict(overrides or {}),
    }
    if shape not in runnable_shapes(cfg):
        rec["status"] = "skipped"
        rec["reason"] = "long_500k requires sub-quadratic attention (DESIGN.md §Arch-applicability)"
        return rec

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec["chips"] = mesh.size
    rules = logical_rules(cfg, shape, mesh)
    model = Model(cfg)
    params_abs = model.abstract_params()
    params_sh = named(mesh, model.param_pspecs(rules))

    def build_step(c):
        if shape.kind == "train":
            _, _opt, s = make_train_step(c, mesh)
        elif shape.kind == "prefill":
            _, s = make_prefill_step(c, mesh)
        else:
            _, s = make_decode_step(c, mesh)
        return s

    if shape.kind == "train":
        step = build_step(cfg)
        opt_abs = {
            "mu": _abstract_f32(params_abs),
            "nu": _abstract_f32(params_abs),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        opt_sh = {
            "mu": params_sh, "nu": params_sh,
            "step": NamedSharding(mesh, P()),
        }
        batch_abs = input_specs(cfg, shape)
        batch_sh = named(mesh, batch_pspecs(cfg, shape, mesh))
        args = (params_abs, opt_abs, batch_abs)
        in_sh = (params_sh, opt_sh, batch_sh)
        out_sh = (params_sh, opt_sh, None)
        donate = (0, 1)
    elif shape.kind == "prefill":
        step = build_step(cfg)
        batch_abs = input_specs(cfg, shape)
        batch_sh = named(mesh, batch_pspecs(cfg, shape, mesh))
        args = (params_abs, batch_abs)
        in_sh = (params_sh, batch_sh)
        out_sh = None
        donate = ()
    else:  # decode
        step = build_step(cfg)
        cache_abs, tok_abs, pos_abs = decode_input_specs(cfg, shape)
        cache_sh = named(mesh, cache_pspecs(cfg, shape, mesh))
        rules_b = logical_rules(cfg, shape, mesh)["batch"]
        tok_sh = NamedSharding(mesh, P(rules_b, None))
        pos_sh = NamedSharding(mesh, P())
        args = (params_abs, cache_abs, tok_abs, pos_abs)
        in_sh = (params_sh, cache_sh, tok_sh, pos_sh)
        out_sh = (None, cache_sh)
        donate = (1,)

    # --- loop-aware jaxpr cost (global totals) ---
    # Pallas kernels can't lower for the CPU SPMD backend, so when the
    # config selects them the COST is derived from the kernel jaxpr (its
    # true HBM traffic/FLOPs) while the COMPILE uses the numerically
    # equivalent chunked lowering — attention adds no collectives, so the
    # collective analysis is unaffected (EXPERIMENTS.md §Perf notes this).
    cost_step = step
    if cfg.attn_impl == "pallas":
        step = build_step(cfg.replace(attn_impl="chunked"))
    t0 = time.perf_counter()
    with activate_sharding(mesh, rules):
        cost = step_cost(cost_step, *args)
    rec["jaxpr_flops"] = cost.flops
    rec["jaxpr_dot_flops"] = cost.dot_flops
    rec["jaxpr_bytes"] = cost.bytes
    rec["jaxpr_collective_bytes"] = cost.collective_bytes
    rec["t_trace_s"] = time.perf_counter() - t0

    # --- lower + compile on the production mesh ---
    t0 = time.perf_counter()
    with activate_sharding(mesh, rules):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
        lowered = jitted.lower(*args)
    rec["t_lower_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    rec["t_compile_s"] = time.perf_counter() - t0

    try:
        mem = compiled.memory_analysis()
        if mem is not None:
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[f"mem_{k}"] = int(v)
    except Exception as e:  # pragma: no cover
        rec["mem_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if ca:
            rec["xla_flops_unscaled"] = float(ca.get("flops", -1.0))
            rec["xla_bytes_unscaled"] = float(ca.get("bytes accessed", -1.0))
    except Exception as e:  # pragma: no cover
        rec["xla_cost_error"] = str(e)

    text = compiled.as_text()
    coll = collective_bytes(text)
    rec["hlo_collective_bytes_per_device"] = coll["bytes_per_device"]
    rec["hlo_collective_counts"] = coll["counts"]
    if coll["warnings"]:
        rec["hlo_warnings"] = coll["warnings"][:10]

    # --- model flops (6ND train / 2ND inference; N_active for MoE) ---
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    rec["param_count"] = n_params
    rec["active_param_count"] = n_active
    rec["model_flops"] = mult * n_active * tokens
    rec["tokens_per_step"] = tokens
    rec["status"] = "ok"
    return rec


ALL_MESHES = ("single", "multi")


def iter_cells():
    from repro.configs import ARCH_IDS, SHAPES

    for arch in ARCH_IDS:
        for shape in SHAPES:
            yield arch, shape


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (python literal), e.g. --set moe_dispatch='scatter'")
    ap.add_argument("--tag", default="", help="artifact suffix for variant runs")
    args = ap.parse_args()

    import ast

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    ART_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [
            (a, s, m)
            for a, s in iter_cells()
            for m in (ALL_MESHES if args.mesh == "both" else (args.mesh,))
        ]
        procs: list = []
        failed = []
        for arch, shape, m in cells:
            out = ART_DIR / f"{arch}__{shape}__{m}.json"
            if out.exists() and not args.force:
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", m,
            ]
            while len(procs) >= args.jobs:
                for p in procs[:]:
                    if p[0].poll() is not None:
                        procs.remove(p)
                        if p[0].returncode != 0:
                            failed.append(p[1])
                            print(f"FAIL {p[1]}", flush=True)
                        else:
                            print(f"done {p[1]}", flush=True)
                time.sleep(1.0)
            procs.append((subprocess.Popen(cmd, stdout=subprocess.DEVNULL), f"{arch}/{shape}/{m}"))
        for p, name in procs:
            p.wait()
            if p.returncode != 0:
                failed.append(name)
                print(f"FAIL {name}", flush=True)
            else:
                print(f"done {name}", flush=True)
        print(f"dry-run complete; {len(failed)} failures: {failed}")
        return 1 if failed else 0

    rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh, "status": "error"}
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, overrides)
    except Exception:
        rec["traceback"] = traceback.format_exc()
        print(rec["traceback"], file=sys.stderr)
    sfx = f"__{args.tag}" if args.tag else ""
    out = ART_DIR / f"{args.arch}__{args.shape}__{args.mesh}{sfx}.json"
    out.write_text(json.dumps(rec, indent=2, default=str))
    print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "status") if k in rec}))
    if rec["status"] == "ok":
        print(f"compile={rec.get('t_compile_s', 0):.1f}s "
              f"flops={rec.get('jaxpr_flops', 0):.3e} "
              f"coll_bytes/dev={rec.get('hlo_collective_bytes_per_device', 0):.3e}")
    return 0 if rec["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
