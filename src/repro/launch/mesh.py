"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
carries pure data parallelism (gradient reduction crosses the inter-pod
links once per step); data/model stay intra-pod.

A function (not a module constant) so importing never touches jax device
state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    import numpy as np

    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def data_axes(mesh) -> tuple:
    """The axes that carry batch data parallelism."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_axis(mesh) -> str:
    return "model"
