"""Small JAX API compatibility layer (pinned against jax 0.8.x)."""

from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with varying-manual-axes checking off (we use psum /
    axis_index freely inside bodies)."""
    return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
