"""Small JAX API compatibility layer.

``shard_map`` moved out of ``jax.experimental`` in jax 0.6 and its
"check the body's replication/varying-manual-axes claims" kwarg was renamed
``check_rep`` -> ``check_vma`` along the way.  Feature-detect at import time
so the same call sites run on both API generations (the pinned environment
ships jax 0.4.x, where only the experimental spelling exists).
"""

from __future__ import annotations

import inspect

import jax

_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

# The kwarg rename did not land in the same release as the top-level
# promotion, so detect it from the signature of whichever function we got,
# not from where the symbol lives.
_CHECK_KWARG = (
    "check_vma" if "check_vma" in inspect.signature(_shard_map).parameters else "check_rep"
)


def shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map with varying-manual-axes checking off (we use psum /
    axis_index freely inside bodies)."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KWARG: False}
    )
