"""Loop-aware cost analysis.

XLA's HloCostAnalysis (what ``compiled.cost_analysis()`` reports) counts a
``while`` body ONCE, so a 60-layer ``lax.scan`` under-reports FLOPs by 60x.
This module walks the *jaxpr* instead: ``scan`` bodies are multiplied by
their static trip count, ``pjit``/``remat``/``custom_*`` sub-jaxprs are
recursed into, and ``shard_map`` bodies (whose avals are per-shard) are
scaled back to global by the mesh size.

Outputs (GLOBAL, whole-step totals):
  * ``flops``            — 2*M*N*K for dot_general/conv, |out| for elementwise
  * ``bytes``            — HBM-traffic estimate: in+out bytes for
                           materializing ops (dot, gather, scatter, reduce,
                           concat, slice/update, collectives, scan carries),
                           out-bytes only for elementwise chains (assumed
                           fused by XLA)
  * ``collective_bytes`` — explicit collectives found in the jaxpr
                           (shard_map psum/all_to_all/...); the pjit-induced
                           collectives (gradient reductions etc.) are counted
                           separately from the partitioned HLO (hlo_parse.py)

The two sources are combined by launch/dryrun.py and reported per cell in
EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import numpy as np

MATERIALIZING = {
    "gather", "scatter", "scatter-add", "dynamic_slice", "dynamic_update_slice",
    "concatenate", "pad", "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax", "cumprod", "sort",
    "top_k", "iota", "reshape", "transpose", "rev", "broadcast_in_dim",
}

COLLECTIVES = {"psum", "all_to_all", "ppermute", "all_gather", "psum_scatter", "pbroadcast"}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    dot_flops: float = 0.0
    notes: list = field(default_factory=list)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.collective_bytes += other.collective_bytes
        self.dot_flops += other.dot_flops
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.collective_bytes * k,
                    self.dot_flops * k, list(self.notes))


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _nelems(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    contract = math.prod(lhs.shape[i] for i in lc) if lc else 1
    lfree = math.prod(
        d for i, d in enumerate(lhs.shape) if i not in lc and i not in lb
    )
    rfree = math.prod(
        d for i, d in enumerate(rhs.shape) if i not in rc and i not in rb
    )
    return 2.0 * batch * lfree * rfree * contract


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for higher-order primitives."""
    p = eqn.primitive.name
    params = eqn.params
    if p == "scan":
        return [(params["jaxpr"].jaxpr, params["length"])]
    if p == "while":
        # no static trip count at jaxpr level; callers of the step fns only
        # use scan, so flag it
        return [(params["body_jaxpr"].jaxpr, 1)]
    if p in ("pjit", "closed_call", "core_call", "custom_vjp_call_jaxpr", "remat2", "remat", "checkpoint"):
        j = params.get("jaxpr") or params.get("call_jaxpr") or params.get("fun_jaxpr")
        if j is not None:
            return [(getattr(j, "jaxpr", j), 1)]
    if p in ("custom_jvp_call", "custom_vjp_call"):
        j = params.get("call_jaxpr") or params.get("fun_jaxpr")
        if j is not None:
            return [(getattr(j, "jaxpr", j), 1)]
    if p == "cond":
        # branch-dependent (CAPre section 4.4!): cost = max over branches
        return [("cond", params["branches"])]
    if p == "shard_map":
        mesh = params.get("mesh")
        size = getattr(mesh, "size", None) or 1
        j = params.get("jaxpr")
        return [(getattr(j, "jaxpr", j), ("shard_map", size))]
    return []


def jaxpr_cost(jaxpr) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        subs = _sub_jaxprs(eqn)
        if subs:
            for sub, mult in subs:
                if sub == "cond":
                    branch_costs = [jaxpr_cost(b.jaxpr) for b in mult]
                    worst = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    total += worst
                elif isinstance(mult, tuple) and mult[0] == "shard_map":
                    body = jaxpr_cost(sub)
                    total += body.scaled(mult[1])  # per-shard -> global
                else:
                    body = jaxpr_cost(sub)
                    total += body.scaled(mult)
            if p == "scan":
                # scan carries stream through HBM once per iteration
                carry_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
                total.bytes += carry_bytes
            if p == "while":
                total.notes.append("while-without-trip-count")
            continue

        out_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_nbytes(v.aval) for v in eqn.invars if hasattr(v, "aval"))
        if p == "pallas_call":
            total += _pallas_cost(eqn, in_bytes, out_bytes)
        elif p == "dot_general":
            f = _dot_flops(eqn)
            total.flops += f
            total.dot_flops += f
            total.bytes += in_bytes + out_bytes
        elif p in ("conv_general_dilated",):
            # rare here; approximate with output * kernel elements * 2
            total.flops += 2.0 * _nelems(eqn.outvars[0].aval) * _nelems(eqn.invars[1].aval)
            total.bytes += in_bytes + out_bytes
        elif p in COLLECTIVES:
            total.collective_bytes += out_bytes
            total.bytes += in_bytes + out_bytes
        elif p == "dynamic_update_slice":
            # donated buffers update in place: traffic = read update + write
            # the touched region (NOT the whole buffer)
            upd = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else out_bytes
            total.bytes += 2 * upd
        elif p in ("gather", "dynamic_slice"):
            # reads only the gathered/sliced rows (+ indices), writes out
            idx = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0
            total.bytes += 2 * out_bytes + idx
        elif p in ("scatter", "scatter-add", "scatter_add"):
            upd = _nbytes(eqn.invars[2].aval) if len(eqn.invars) > 2 else out_bytes
            total.bytes += 2 * upd
        elif any(p.startswith(m) or p == m for m in MATERIALIZING) or p.startswith("reduce"):
            total.flops += _nelems(eqn.outvars[0].aval) if eqn.outvars else 0
            total.bytes += in_bytes + out_bytes
        else:
            # elementwise: assume fused into producers; count the write
            total.flops += sum(_nelems(v.aval) for v in eqn.outvars)
            total.bytes += out_bytes
    return total


def _pallas_cost(eqn, in_bytes: float, out_bytes: float) -> Cost:
    """Kernel-true costs: Pallas kernels stream operands HBM->VMEM exactly
    once (the grid pipeline) and keep intermediates in VMEM, so bytes =
    operands + results; FLOPs computed per kernel from operand shapes."""
    name = str(eqn.params.get("name", "")) or str(eqn.params.get("name_and_src_info", ""))
    c = Cost(bytes=in_bytes + out_bytes)
    avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
    if "flash_bwd_dkdv" in name and len(avals) >= 2:
        q, k = avals[0], avals[1]
        BH, Sq, D = q.shape[-3:]
        Sk = k.shape[-2]
        c.flops = 8.0 * BH * Sq * Sk * D  # qk recompute + dp + dv + dk
        c.dot_flops = c.flops
    elif "flash_bwd_dq" in name and len(avals) >= 2:
        q, k = avals[0], avals[1]
        BH, Sq, D = q.shape[-3:]
        Sk = k.shape[-2]
        c.flops = 6.0 * BH * Sq * Sk * D  # qk recompute + dp + dq
        c.dot_flops = c.flops
    elif "flash" in name and len(avals) >= 2:
        q, k = avals[0], avals[1]  # [BH, Sq, D], [BKV, Sk, D]
        BH, Sq, D = q.shape[-3:]
        Sk = k.shape[-2]
        c.flops = 4.0 * BH * Sq * Sk * D  # qk^T + pv
        c.dot_flops = c.flops
    elif "decode" in name and len(avals) >= 3:
        q, k = avals[1], avals[2]  # (len, q [BH, D], k [BKV, S, D], ...)
        BH, D = q.shape[-2:]
        Sk = k.shape[-2]
        c.flops = 4.0 * BH * Sk * D
        c.dot_flops = c.flops
    else:
        c.flops = sum(_nelems(v.aval) for v in eqn.outvars)
    return c


def step_cost(fn, *abstract_args, **kw) -> Cost:
    jaxpr = jax.make_jaxpr(fn, **kw)(*abstract_args)
    return jaxpr_cost(jaxpr.jaxpr)
