"""Collective extraction from the partitioned (post-SPMD) HLO text.

``compiled.as_text()`` shapes are PER-DEVICE after partitioning.  We sum the
output-shape bytes of every collective op (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute), multiplying ops inside
``while`` bodies by the loop trip count (extracted from the comparison
constant in the condition computation — the form ``lax.scan`` lowers to).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(
    r"\b(all-gather-start|all-gather-done|all-gather|"
    r"all-reduce-start|all-reduce-done|all-reduce|"
    r"reduce-scatter|all-to-all|"
    r"collective-permute-start|collective-permute-done|collective-permute|"
    r"while|fusion|call|conditional|async-start)\("
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type text like
    ``(f32[8,128], bf16[4])`` or ``f32[8,128]``."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class _Computation:
    name: str
    collective_bytes: float = 0.0
    collective_counts: dict = field(default_factory=dict)
    # (body_comp, cond_comp) pairs for while ops in this computation
    whiles: list = field(default_factory=list)
    # other called computations (fusions, call) — counted once
    calls: list = field(default_factory=list)
    max_constant: int = 1


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-$]+)\s*\(.*\)\s*->\s*.+\{\s*$")


def parse_computations(hlo_text: str):
    """Returns (computations dict, entry computation name or None)."""
    comps: dict[str, _Computation] = {}
    entry_name = None
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        header = _HEADER_RE.match(stripped)
        if header and "=" not in stripped.split("(")[0]:
            cur = _Computation(name=header.group(2))
            comps[cur.name] = cur
            if header.group(1):
                entry_name = cur.name
            continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        s = line.strip()
        # constants (for while trip counts): s32[] constant(123)
        mc = re.search(r"constant\((\d+)\)", s)
        if mc:
            cur.max_constant = max(cur.max_constant, int(mc.group(1)))
        m = _INSTR_RE.match(s)
        if not m:
            continue
        rest = m.group(2)
        # find "<type> <opcode>(" by searching for a known opcode token
        op_m = _OPCODE_RE.search(rest)
        if not op_m:
            continue
        type_str, opcode = rest[: op_m.start()], op_m.group(1)
        if opcode.endswith("-done"):
            continue  # async pair: bytes counted at the -start op
        if opcode == "while":
            body = re.search(r"body=%?([\w.\-]+)", rest)
            cond = re.search(r"condition=%?([\w.\-]+)", rest)
            if body and cond:
                cur.whiles.append((body.group(1), cond.group(1)))
        elif opcode in ("fusion", "call", "conditional", "async-start"):
            for cm in re.finditer(r"(?:calls|to_apply|branch_computations=\{)=?%?([\w.\-]+)", rest):
                cur.calls.append(cm.group(1))
        elif any(opcode == c or opcode.startswith(c + "-") for c in COLLECTIVE_OPS):
            base = next(c for c in COLLECTIVE_OPS if opcode.startswith(c))
            b = _shape_bytes(type_str)
            cur.collective_bytes += b
            cur.collective_counts[base] = cur.collective_counts.get(base, 0) + 1
    return comps, entry_name


def collective_bytes(hlo_text: str, entry: str | None = None) -> dict:
    """Per-device collective bytes for the entry computation, with while
    bodies multiplied by their trip counts."""
    comps, entry_name = parse_computations(hlo_text)
    if not comps:
        return {"bytes_per_device": 0.0, "counts": {}, "warnings": ["no computations parsed"]}
    if entry is None:
        entry = entry_name or next(
            (n for n in comps if n.startswith("main") or "entry" in n), next(iter(comps))
        )
    warnings: list[str] = []
    counts: dict[str, float] = {}

    def visit(name: str, mult: float, seen: tuple) -> float:
        comp = comps.get(name)
        if comp is None or name in seen:
            return 0.0
        total = comp.collective_bytes * mult
        for op, c in comp.collective_counts.items():
            counts[op] = counts.get(op, 0) + c * mult
        for body, cond in comp.whiles:
            trip = comps[cond].max_constant if cond in comps else 1
            if trip <= 1:
                warnings.append(f"while {body}: trip count not found, using 1")
                trip = 1
            total += visit(body, mult * trip, seen + (name,))
        for callee in comp.calls:
            total += visit(callee, mult, seen + (name,))
        return total

    total = visit(entry, 1.0, ())
    return {"bytes_per_device": total, "counts": counts, "warnings": warnings}
