from .adamw import AdamW, clip_by_global_norm  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
