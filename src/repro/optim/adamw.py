"""AdamW in pure JAX (no optax dependency), with global-norm clipping.

Optimizer state mirrors the parameter tree (mu/nu per leaf), so the same
PartitionSpecs shard it — the states of a sharded weight live with its
shards (ZeRO-1-style for TP'd weights, replicated-with-DP like the params).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gnorm


@dataclass(frozen=True)
class AdamW:
    learning_rate: Union[float, Callable[[jax.Array], jax.Array]] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params):
        """Returns (new_params, new_state, metrics)."""
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["nu"], grads
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gnorm, "lr": lr}
