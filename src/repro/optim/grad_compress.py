"""Gradient compression for cross-pod data parallelism.

At 512+ chips the inter-pod gradient reduction crosses the slow links; int8
quantization with error feedback cuts that traffic 4x with negligible
quality loss (standard large-fleet trick).  Implemented as a shard_map around the
pod-axis reduction so the quantized representation is what crosses the pod
boundary; intra-pod reductions stay full precision.

``compress_update`` is pure and unit-tested: quantize -> psum -> dequantize
with per-tensor scales and an error-feedback residual carried in the
optimizer state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, residual):
    """Error-feedback int8 compression of one gradient leaf.

    Returns (decompressed gradient as would be seen after the wire,
    new residual)."""
    g32 = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(g32)
    deq = dequantize_int8(q, scale)
    return deq, g32 - deq


def compressed_psum_tree(grads, residuals, axis_name: str):
    """Quantize each leaf, psum the int8 payloads over ``axis_name``
    (summing int32 accumulations of int8 wires), dequantize, and return the
    mean gradient plus new residuals.  Must run inside shard_map with
    ``axis_name`` bound."""
    n = jax.lax.psum(1, axis_name)

    def per_leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        # the wire format: int8 payload + f32 scale per participant
        acc = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale, axis_name)
        deq_local = dequantize_int8(q, scale)
        return acc / n, g32 - deq_local

    flat, treedef = jax.tree.flatten(grads)
    rflat, _ = jax.tree.flatten(residuals)
    out, res = [], []
    for g, r in zip(flat, rflat):
        o, nr = per_leaf(g, r)
        out.append(o)
        res.append(nr)
    return jax.tree.unflatten(treedef, out), jax.tree.unflatten(treedef, res)


def make_compressed_allreduce(mesh, axis: str = "pod"):
    """Returns fn(grads, residuals) -> (mean grads, residuals) running the
    compressed reduction over the given mesh axis via shard_map; other axes
    untouched (their reductions happen inside the step as usual)."""
    from repro.launch.compat import shard_map

    def fn(grads, residuals):
        specs = jax.tree.map(lambda _: P(), grads)

        def body(g, r):
            return compressed_psum_tree(g, r, axis)

        return shard_map(
            body, mesh=mesh,
            in_specs=(specs, specs),
            out_specs=(specs, specs),
        )(grads, residuals)

    return fn
