"""Quickstart: the paper's running example end to end.

Registers the Listing-1 bank application with the POS (CAPre intercepts
registration, runs Algorithm 1 and generates the prefetch methods), stores a
dataset distributed over 4 Data Services, executes
``setAllTransCustomers()`` with and without CAPre, and prints the
prefetching hints, the accuracy accounting, and the wall-clock effect.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.apps.bank import build_bank_app, populate_bank_store
from repro.pos.client import POSClient
from repro.pos.latency import LatencyModel


def main() -> None:
    app = build_bank_app()

    lat = LatencyModel(disk_load=300e-6, remote_hop=120e-6, write_back=350e-6, think=100e-6)
    client = POSClient(n_services=4, latency=lat)
    reg = client.register(app)

    print("=== CAPre static analysis (compile-time, section 4/5) ===")
    print(f"analysis took {reg.analysis_time_s*1e3:.1f} ms "
          f"(lowering {reg.lowering_time_s*1e3:.1f} ms)")
    for key, hints in sorted(reg.report.hints.items()):
        if hints:
            print(f"  PH[{key}] = {{{', '.join(str(h) for h in hints)}}}")

    print("\n=== execution: 300 transactions over 4 Data Services ===")
    for mode in (None, "capre"):
        root = populate_bank_store(client.store, n_transactions=300)
        client.store.reset_runtime_state()
        with client.session("bank", mode=mode, parallel_workers=16) as s:
            t0 = time.perf_counter()
            s.execute(root, "setAllTransCustomers")
            wall = time.perf_counter() - t0
            s.drain(10.0)
        m = client.store.snapshot_metrics()
        acc = client.store.prefetch_accuracy()
        label = mode or "no prefetch"
        print(f"  {label:12s}: {wall*1e3:7.1f} ms  "
              f"misses={m['app_cache_misses']:5d} hits={m['app_cache_hits']:5d} "
              f"prefetched={m['prefetch_loads']:5d} recall={acc['recall']:.2f}")


if __name__ == "__main__":
    main()
