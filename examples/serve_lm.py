"""Serving example: batched prefill + decode with the CAPre access plan and
plan-driven weight streaming.

The decode step's parameter access plan is derived statically (jaxpr
analysis — the paper's compile-time hints), then the same plan drives a
host->device weight streamer whose background executor keeps the layer
stack ahead of the compute frontier, compared against ROP-depth and
on-demand baselines.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.access_plan import build_access_plan, rop_plan
from repro.launch.serve import Server
from repro.launch.steps import concrete_batch
from repro.models.model import Model
from repro.runtime.prefetch import HostParamStore, WeightStreamer


def main() -> None:
    cfg = get_smoke_config("yi_34b").replace(n_layers=12, d_model=256, d_ff=768,
                                             n_heads=8, n_kv_heads=2, head_dim=0)
    server = Server(cfg, max_len=64)
    model = server.model
    params = model.init_params(jax.random.PRNGKey(0))

    print("=== batched serving (prefill + decode) ===")
    batch = concrete_batch(cfg, 4, 32)
    batch.pop("targets")
    t0 = time.perf_counter()
    tokens = server.generate(params, batch, steps=16)
    dt = time.perf_counter() - t0
    print(f"generated {tokens.shape[0]}x{tokens.shape[1]} tokens in {dt:.2f}s")

    print("\n=== CAPre access plan for one decode step ===")
    plan = server.plan(batch_size=4)
    print(f"{len(plan.records)} records, {len(plan.collections())} collections, "
          f"{plan.total_bytes/1e6:.1f} MB predicted per step")
    for h in plan.hints()[:6]:
        print("  hint:", h)

    print("\n=== plan-driven weight streaming vs baselines ===")
    for mode in (None, "rop", "capre"):
        store = HostParamStore(params, bandwidth_gbps=1.0, base_latency_s=400e-6)
        ws = WeightStreamer(store, plan=plan, mode=mode, k_ahead=3, workers=8)
        wall = ws.run_plan(compute_s_per_group=1.5e-3)
        m = ws.metrics
        ws.close()
        print(f"  {mode or 'on-demand':10s}: {wall*1e3:7.1f} ms "
              f"stalls={m.stalls:3d} stall_time={m.stall_seconds*1e3:6.1f} ms "
              f"prefetch_hits={m.prefetch_hits}")


if __name__ == "__main__":
    main()
