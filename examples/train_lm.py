"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on CPU with the full production stack — config, data pipeline with
background prefetch, AdamW + warmup-cosine, async atomic checkpointing, and
resume-from-checkpoint.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
(defaults keep CPU wall time reasonable; pass --steps 300 for the full run)
"""

import argparse
import tempfile

from repro.configs import get_config
from repro.launch.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # a ~100M-parameter reduction of the chatglm3 family (same components)
    cfg = get_config("chatglm3_6b").replace(
        n_layers=10, d_model=768, n_heads=12, n_kv_heads=2, head_dim=0,
        d_ff=2048, vocab_size=32_000, remat="none", attn_chunk=128,
    )
    n = cfg.param_count()
    print(f"model: {n/1e6:.1f}M params ({cfg.n_layers}L d={cfg.d_model})")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg, global_batch=args.batch, seq_len=args.seq,
            ckpt_dir=ckpt_dir, total_steps=args.steps, log_every=10,
        )
        params, opt_state, losses = trainer.train(args.steps, save_every=100)
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
        assert losses[-1] < losses[0], "training must reduce the loss"

        # prove resume: a fresh trainer restores from the checkpoint
        trainer2 = Trainer(
            cfg, global_batch=args.batch, seq_len=args.seq,
            ckpt_dir=ckpt_dir, total_steps=args.steps,
        )
        p, o = trainer2.init_state()
        start, _, _ = trainer2.maybe_restore(p, o)
        print(f"resume check: restored step {start}")


if __name__ == "__main__":
    main()
