"""CAPre vs ROP on the paper's four benchmarks (reduced sizes).

Prints the execution-time comparison table the paper's section 7 draws:
CAPre's code-derived hints vs the schema-heuristic Referenced-Objects
Predictor at several fetch depths, on OO7 t1, Wordcount, K-Means, and both
PGA algorithms.

Run: PYTHONPATH=src python examples/capre_vs_rop.py
"""

from benchmarks.bench_kmeans import run as kmeans_run
from benchmarks.bench_oo7 import bench_t1
from benchmarks.bench_pga import run as pga_run
from benchmarks.bench_wordcount import run as wc_run
from benchmarks.common import print_results


def main() -> None:
    print("name,us_per_call,derived")
    results = []
    results += bench_t1(reps=1, sizes=("small",))
    results += wc_run(reps=1, chunk_sweep=(64,))
    results += kmeans_run(reps=1, sizes=(400,))
    results += pga_run(reps=1, n_vertices=200)
    print_results(results)


if __name__ == "__main__":
    main()
