"""Fidelity tests against the paper's running example (Listing 1, Figures 2-3,
and the PH_m set printed in section 4.3)."""

import pytest

from repro.apps.bank import build_bank_app
from repro.core import lang
from repro.core.hints import analyze_application
from repro.core.lower import lower_method
from repro.core.rop import rop_hints
from repro.core.type_graph import (
    CAPreAnalysis,
    EXCLUDE_BRANCH_DEPENDENT,
    INCLUDE_BRANCH_DEPENDENT,
)


@pytest.fixture(scope="module")
def app():
    return build_bank_app()


def test_application_type_graph_matches_figure_2a(app):
    """Section 4.2.1 example associations of G_T."""
    assoc = app.type_graph()
    assert assoc[("BankManagement", "transactions")] == ("Transaction", lang.COLLECTION)
    assert assoc[("Transaction", "account")] == ("Account", lang.SINGLE)
    assert assoc[("Employee", "dept")] == ("Department", lang.SINGLE)
    assert assoc[("Account", "cust")] == ("Customer", lang.SINGLE)
    assert assoc[("Customer", "company")] == ("Company", lang.SINGLE)
    assert ("TransactionType", "typeID") not in assoc  # primitive: not in G_T


def test_ir_of_setalltranscustomers_matches_listing_2(app):
    """The lowered IR follows the Listing 2 pattern: getfield transactions,
    iterator(), hasNext(), conditional branch, next(), getAccount(),
    getfield manager, setCustomer(), goto."""
    mir = lower_method(app, app.method("BankManagement", "setAllTransCustomers"))
    kinds = [i.itype for i in mir.instrs]
    assert kinds == [
        "getfield",  # v2 = getfield transactions : v1
        "iterator",  # v3 = iterator() : v2
        "hasnext",  # v4 = hasNext() : v3
        "conditionalbranch",
        "next",  # v5 = next() : v3
        "invokemethod",  # v6 = getAccount() : v5
        "getfield",  # v7 = getfield manager : v1
        "invokemethod",  # setCustomer() : v6, v7
        "goto",
    ]
    nxt = mir.instrs[4]
    assert nxt.has_loop_parent and not nxt.has_conditional_parent
    inv = mir.instrs[7]
    assert inv.used_vars == ("v6", "v7")


def test_getaccount_branch_dependence_matches_figure_2b(app):
    """In getAccount(): `type` is navigated in the condition (never branch
    dependent); `emp` is navigated in BOTH branches (the paper's observation
    that such navigations are effectively branch-independent); `emp.dept`
    only in the else branch (branch-dependent, orange in Fig. 2b);
    `account` is the returned navigation."""
    analysis = CAPreAnalysis(app)
    g = analysis.graph_of("Transaction.getAccount")
    root = g.this_root
    assert set(root.children) == {"type", "emp", "account"}
    assert not root.children["type"].branch_dependent
    assert not root.children["emp"].branch_dependent
    dept = root.children["emp"].children["dept"]
    assert dept.branch_dependent
    assert root.children["account"].is_return


def test_ph_m_exclude_policy_matches_paper_section_4_3(app):
    """The PH_m printed in section 4.3:
    {transactions.type, transactions.emp, transactions.account.cust.company,
     manager.company} — reproduced exactly under the conservative policy
    (the printed set omits the branch-dependent emp.dept)."""
    report = analyze_application(app, policy=EXCLUDE_BRANCH_DEPENDENT)
    got = report.hints_str("BankManagement.setAllTransCustomers")
    assert got == {
        "transactions[].type",
        "transactions[].emp",
        "transactions[].account.cust.company",
        "manager.company",
    }


def test_ph_m_include_policy_adds_branch_dependent_dept(app):
    """CAPre's implementation choice (section 4.4): include branch-dependent
    navigations — the union of both branches adds transactions[].emp.dept."""
    report = analyze_application(app, policy=INCLUDE_BRANCH_DEPENDENT)
    got = report.hints_str("BankManagement.setAllTransCustomers")
    assert got == {
        "transactions[].type",
        "transactions[].emp.dept",
        "transactions[].account.cust.company",
        "manager.company",
    }


def test_caller_dedup_empties_invoked_methods(app):
    """Section 5.1.3: hints found in all callers are removed — getAccount and
    setCustomer are only invoked by setAllTransCustomers, which already
    prefetches everything they would."""
    report = analyze_application(app)
    assert report.full_hints_str("Transaction.getAccount") != set()
    assert report.hints_str("Transaction.getAccount") == set()
    assert report.hints_str("Account.setCustomer") == set()
    # the entry method keeps its hints
    assert report.hints_str("BankManagement.setAllTransCustomers") != set()


def test_rop_hints_depth_expansion(app):
    """Section 3: ROP with depth 1 on Transaction predicts TransactionType,
    Account and Employee; depth 2 adds Department and Customer; collections
    are never predicted."""
    d1 = {str(h) for h in rop_hints(app, "Transaction", 1)}
    assert d1 == {"type", "account", "emp"}
    d2 = {str(h) for h in rop_hints(app, "Transaction", 2)}
    assert d2 == {"type", "account.cust", "emp.dept"}
    d3 = {str(h) for h in rop_hints(app, "Transaction", 3)}
    assert d3 == {"type", "account.cust.company", "emp.dept"}
    # ROP on BankManagement never predicts the transactions collection
    bm = {str(h) for h in rop_hints(app, "BankManagement", 5)}
    assert all("transactions" not in h for h in bm)
    assert "manager.company" in bm


def test_no_branch_dependent_stats(app):
    report = analyze_application(app)
    s = report.stats
    # incl. the write-dense creditAll companion and the early-exit
    # findLargeTransaction scan (the partial-traversal truncation exemplar)
    assert s.n_methods == 8
    # getAccount triggers a branch-dependent navigation (emp.dept), and the
    # augmented graph of setAllTransCustomers inherits it — for both, the
    # predicted set is inexact (Fig. 5b counts exactly this property).
    assert s.n_methods_no_bd == 5
    assert s.n_conditionals >= 2
