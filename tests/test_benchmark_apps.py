"""Tests for the four paper-benchmark applications: static-analysis hints,
execution correctness against pure-Python oracles, and the structural claims
the paper's evaluation relies on."""

from collections import Counter

import pytest

from repro.apps.kmeans import build_kmeans_app, initial_centroids, populate_kmeans, _nearest
from repro.apps.oo7 import build_oo7_app, populate_oo7
from repro.apps.pga import build_pga_app, populate_pga
from repro.apps.wordcount import build_wordcount_app, populate_wordcount
from repro.core.hints import analyze_application
from repro.core.rop import rop_hints
from repro.pos.client import POSClient
from repro.pos.latency import ZERO


# ---------------------------------------------------------------------------
# Static analysis
# ---------------------------------------------------------------------------


def test_wordcount_hints():
    report = analyze_application(build_wordcount_app())
    got = report.hints_str("WCJob.run")
    assert got == {"collections[].texts[].stats", "collections[].texts[].chunks[]"}


def test_kmeans_hints_and_rop_has_nothing():
    app = build_kmeans_app()
    report = analyze_application(app)
    assert report.hints_str("KMeansJob.run") == {"collections[].vectors[]"}
    # Figure 14's explanation: the KMeans model has no single associations.
    for cls in app.classes:
        assert rop_hints(app, cls, 5) == ()


def test_pga_dfs_vs_bellman_ford_hints():
    """DFS exposes the vertex/edge collections to the analysis; Bellman-Ford's
    worklist traversal exposes nothing (the paper's 7.2.4 contrast)."""
    report = analyze_application(build_pga_app())
    dfs = report.hints_str("WeightedDirectedGraph.dfs")
    assert dfs == {"vertices[].edges[].toVertex"}
    bf = report.hints_str("WeightedDirectedGraph.bellmanFord")
    assert bf == set()


def test_oo7_hints_respect_override_exclusion():
    """sub.traverse() is polymorphic (ComplexAssembly/BaseAssembly override
    Assembly.traverse), so t1's static hints stop at the first assembly level;
    BaseAssembly.traverse keeps its own hints (it is invoked dynamically, so
    no static caller dedups them) — each level prefetches at runtime."""
    report = analyze_application(build_oo7_app())
    t1 = report.hints_str("OO7Bench.t1")
    assert t1 == {"module.designRoot.subAssemblies[]"}
    base = report.hints_str("BaseAssembly.traverse")
    assert "components[].documentation" in base
    assert "components[].rootPart.to[].toPart" in base


# ---------------------------------------------------------------------------
# Execution correctness against oracles
# ---------------------------------------------------------------------------


def _client(app):
    c = POSClient(n_services=4, latency=ZERO)
    c.register(app)
    return c


@pytest.mark.parametrize("mode", [None, "capre", ("rop", 2)])
def test_wordcount_result_matches_oracle(mode):
    c = _client(build_wordcount_app())
    root = populate_wordcount(c.store, chunks_per_text=8, words_per_chunk=16)
    kwargs = {"mode": mode} if not isinstance(mode, tuple) else {"mode": mode[0], "rop_depth": mode[1]}
    with c.session("wordcount", **kwargs) as s:
        got = s.execute(root, "run")
        assert s.drain(10.0)
    # oracle: count every word in the store directly
    expect = Counter()
    for tc in c.store.peek(root).fields["collections"]:
        for t in c.store.peek(tc).fields["texts"]:
            for ch in c.store.peek(t).fields["chunks"]:
                expect.update(c.store.peek(ch).fields["words"])
    assert got == expect


def test_kmeans_result_matches_oracle():
    c = _client(build_kmeans_app())
    root = populate_kmeans(c.store, n_vectors=80, dims=4)
    cents = initial_centroids(k=4, dims=4)
    with c.session("kmeans", mode="capre") as s:
        got = s.execute(root, "run", [list(x) for x in cents])
        assert s.drain(10.0)

    # oracle: run the same lloyd iterations in pure python
    vectors = []
    for vc in c.store.peek(root).fields["collections"]:
        for v in c.store.peek(vc).fields["vectors"]:
            vectors.append(c.store.peek(v).fields["dims"])
    ref = [list(x) for x in cents]
    for _ in range(c.store.peek(root).fields["iters"]):
        sums = [[0.0] * 4 for _ in range(4)]
        counts = [0] * 4
        for dims in vectors:
            cl = _nearest(dims, ref)
            sums[cl] = [a + b for a, b in zip(sums[cl], dims)]
            counts[cl] += 1
        ref = [
            [s / counts[i] for s in sums[i]] if counts[i] else ref[i] for i in range(4)
        ]
    for a, b in zip(got, ref):
        assert a == pytest.approx(b)


def test_pga_bellman_ford_matches_oracle():
    c = _client(build_pga_app())
    g, src = populate_pga(c.store, n_vertices=60, out_degree=3)
    with c.session("pga", mode="capre") as s:
        from repro.pos.interp import ObjRef

        dist = s.execute(g, "bellmanFord", ObjRef(src))
        assert s.drain(10.0)

    # oracle: dijkstra-ish relaxation in pure python (non-negative weights)
    import heapq

    adj: dict[int, list[tuple[int, float]]] = {}
    for v in c.store.peek(g).fields["vertices"]:
        edges = []
        for e in c.store.peek(v).fields["edges"]:
            rec = c.store.peek(e)
            edges.append((rec.fields["toVertex"], rec.fields["weight"]))
        adj[v] = edges
    ref = {src: 0.0}
    pq = [(0.0, src)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > ref.get(u, float("inf")):
            continue
        for v, w in adj[u]:
            nd = d + w
            if nd < ref.get(v, float("inf")):
                ref[v] = nd
                heapq.heappush(pq, (nd, v))
    got = {k.oid: v for k, v in dist.items()}
    assert got.keys() == ref.keys()
    for k in ref:
        assert got[k] == pytest.approx(ref[k])


def test_pga_dfs_visits_everything_once():
    c = _client(build_pga_app())
    g, _ = populate_pga(c.store, n_vertices=50, out_degree=3)
    with c.session("pga") as s:
        total = s.execute(g, "dfs")
    # every edge weight counted exactly once on the DFS tree? No — DFS sums
    # w for each edge scanned (all edges) plus subtree sums; just check the
    # graph was fully visited:
    verts = set(c.store.peek(g).fields["vertices"])
    assert verts <= c.store.accessed_oids
    assert total > 0


def test_oo7_t1_visits_all_atomic_parts():
    c = _client(build_oo7_app())
    root = populate_oo7(c.store, size="small")
    with c.session("oo7", mode="capre") as s:
        s.execute(root, "t1")
        assert s.drain(20.0)
    atomic = {
        oid
        for ds in c.store.services
        for oid, rec in ds.disk.items()
        if rec.cls == "AtomicPart"
    }
    assert atomic <= c.store.accessed_oids


def test_oo7_t2b_write_counts():
    c = _client(build_oo7_app())
    root = populate_oo7(c.store, size="small")
    with c.session("oo7") as s:
        s.execute(root, "t2b")
    atomic_count = sum(
        1 for ds in c.store.services for rec in ds.disk.values() if rec.cls == "AtomicPart"
    )
    # two SetField per updatePart
    assert c.store.metrics.writes == 2 * atomic_count


def test_kmeans_capre_recall_perfect():
    c = _client(build_kmeans_app())
    root = populate_kmeans(c.store, n_vectors=100, dims=4)
    with c.session("kmeans", mode="capre") as s:
        s.execute(root, "run", initial_centroids(k=4, dims=4))
        assert s.drain(10.0)
    acc = c.store.prefetch_accuracy()
    assert acc["recall"] >= 0.99
    assert acc["false_positives"] == 0
