"""Hypothesis property tests over the eviction subsystem: random
access/write/prefetch/drop sequences against every policy, per-service and
shared-budget, checking the cache-accounting invariants of
``test_eviction_policies._run_invariant_sequence``:

  * cache size never exceeds capacity (per service, or globally under a
    shared budget);
  * ``flushed_writes == dirty_evictions + explicit drop_cache flushes``;
  * no oid is simultaneously resident and evicted (the policy's tracked
    set always equals the host's cache membership; dirty lines are always
    resident);
  * metrics are identical after ``reset_runtime_state`` + replay of the
    same sequence (no state leaks across benchmark repetitions).

Kept separate from the deterministic suite because the importorskip guard
skips a whole module — the seeded fallback sweep must still run where
hypothesis is not installed.
"""

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from test_eviction_policies import (
    N_OBJECTS,
    OP_KINDS,
    TEST_POLICIES,
    _run_invariant_sequence,
)

_ops_strategy = st.lists(
    st.tuples(st.sampled_from(OP_KINDS), st.integers(0, N_OBJECTS - 1)),
    max_size=120,
)


@pytest.mark.parametrize("policy", TEST_POLICIES)
@settings(max_examples=25, deadline=None)
@given(
    capacity=st.sampled_from((0, 1, 2, 3, 5, 8)),
    shared=st.booleans(),
    ops=_ops_strategy,
)
def test_cache_accounting_invariants_hold_for_every_policy(policy, capacity, shared, ops):
    _run_invariant_sequence(policy, capacity, shared, ops)
