"""The pluggable eviction subsystem (``pos.eviction``, DESIGN.md §3.5):
per-policy mechanics, the shared-memory-budget mode, property-based
invariants over random access/write/prefetch/drop sequences, replay
determinism, and the thrash-crossover regression the prefetch-aware policy
exists for.

The policy matrix honors ``CAPRE_TEST_POLICIES`` (comma-separated) so CI
can shard the suite across policies; default is every registered policy.
"""

import csv
import io
import os
import random

import pytest

from repro.pos.eviction import (
    DEFAULT_POLICY,
    POLICIES,
    ClockPolicy,
    LFUPolicy,
    LRUPolicy,
    PrefetchAwarePolicy,
    make_policy,
)
from repro.pos.latency import ZERO
from repro.pos.store import ObjectStore
from repro.predict.evaluate import (
    VirtualReplay,
    _catalog,
    evaluate_workload,
    record_workload,
    write_csv,
)

ALL_POLICIES = tuple(POLICIES)
TEST_POLICIES = tuple(
    p for p in os.environ.get("CAPRE_TEST_POLICIES", ",".join(ALL_POLICIES)).split(",") if p
)


# ---------------------------------------------------------------------------
# policy mechanics (pure, no store)
# ---------------------------------------------------------------------------


def test_registry_knows_every_policy_and_rejects_unknown():
    assert set(POLICIES) == {"lru", "fifo", "clock", "lfu", "prefetch-aware"}
    assert DEFAULT_POLICY == "lru"
    for name in POLICIES:
        assert make_policy(name, capacity=4).name == name
    with pytest.raises(KeyError, match="unknown eviction policy"):
        make_policy("mru")


def test_lru_bumps_on_access():
    p = LRUPolicy(capacity=3)
    for oid in (1, 2, 3):
        p.note_insert(oid)
    p.note_access(1)
    assert p.pick_victim() == 2  # 1 was bumped past it
    assert p.tracked() == {1, 3}


def test_fifo_ignores_accesses():
    p = make_policy("fifo", capacity=3)
    for oid in (1, 2, 3):
        p.note_insert(oid)
    p.note_access(1)
    p.note_access(1)
    assert p.pick_victim() == 1  # insertion order, recency irrelevant


def test_clock_gives_referenced_lines_a_second_chance():
    p = ClockPolicy(capacity=3)
    for oid in (1, 2, 3):
        p.note_insert(oid)
    p.note_access(1)
    assert p.pick_victim() == 2  # 1 spared once (bit cleared), hand moves on
    assert p.pick_victim() == 3
    assert p.pick_victim() == 1  # bit was cleared: evicted on the next sweep


def test_lfu_evicts_coldest_with_lru_tiebreak():
    p = LFUPolicy(capacity=4)
    for oid in (1, 2, 3):
        p.note_insert(oid)  # freq 1 each
    p.note_access(1)  # freq 2
    assert p.pick_victim() == 2  # freq 1, inserted before 3
    p.note_insert(4)  # freq 1
    assert p.pick_victim() == 3  # freq-1 tie {3, 4}: 3 is least recent
    assert p.pick_victim() == 4
    assert p.pick_victim() == 1  # the hottest line goes last
    assert p.tracked() == set()


def test_prefetch_aware_protects_flood_head_and_releases_on_use():
    p = PrefetchAwarePolicy(capacity=4, window=2)
    for oid in (1, 2, 3, 4):
        p.note_insert(oid, prefetch=True)
    # pending {1,2,3,4}, window 2 -> beyond-window victims newest-first
    assert p.pick_victim() == 4
    assert p.protected_evictions == 1
    p.note_access(1)  # the app used 1: protection ends, 1 joins recency
    p.note_insert(5)  # demand line
    # victims: pending beyond window? pending {2,3} == window -> recency LRU
    assert p.pick_victim() == 1
    assert p.protected_evictions == 2  # 2,3 were spared
    assert p.pick_victim() == 5
    # forced: only protected pending lines remain -> oldest goes
    assert p.pick_victim() == 2
    assert p.protected_evictions == 3  # the forced eviction spared nothing


def test_prefetch_touch_does_not_count_as_use():
    p = PrefetchAwarePolicy(capacity=2, window=1)
    p.note_insert(1, prefetch=True)
    p.note_insert(2)
    p.note_access(1, prefetch=True)  # a second prefetch of 1: still pending
    assert p.pick_victim() == 2  # the demand line goes; 1 stays protected
    p.note_access(1)  # a real use
    p.note_insert(3, prefetch=True)
    assert p.pick_victim() == 1  # now just a recency line


def test_default_window_is_half_capacity():
    assert PrefetchAwarePolicy(capacity=8).window == 4
    assert PrefetchAwarePolicy(capacity=1).window == 1
    assert PrefetchAwarePolicy(capacity=8, window=7).window == 7


# ---------------------------------------------------------------------------
# store-level behavior per policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", TEST_POLICIES)
def test_store_respects_capacity_under_policy(policy):
    store = ObjectStore(n_services=2, cache_capacity=3, cache_policy=policy)
    oids = [store.put("X", {}) for _ in range(12)]
    for o in oids:
        store.app_access(None, o)
    for ds in store.services:
        assert len(ds.cache) <= 3
        assert ds.policy.tracked() == set(ds.cache)
    assert sum(ds.evictions for ds in store.services) == 12 - 6


@pytest.mark.parametrize("policy", TEST_POLICIES)
def test_shared_budget_enforces_global_capacity(policy):
    store = ObjectStore(n_services=4, cache_capacity=5, cache_policy=policy,
                        shared_budget=True)
    oids = [store.put("X", {}) for _ in range(20)]
    for o in oids:
        store.app_access(None, o)
    total = sum(len(ds.cache) for ds in store.services)
    assert total == 5  # one global budget, not 5 per service
    assert set(store.budget.owner) == {o for ds in store.services for o in ds.cache}
    assert store.budget.policy.tracked() == set(store.budget.owner)
    # stealing happened: at least one service lost a line it loaded
    assert sum(ds.evictions for ds in store.services) == 15


def test_shared_budget_steals_dirty_lines_and_flushes_on_victim_service():
    store = ObjectStore(n_services=2, cache_capacity=2, shared_budget=True)
    a = store.put("X", {}, ds=1)
    b = store.put("X", {}, ds=0)
    c = store.put("X", {}, ds=0)
    store.app_write(a)  # dirty on ds1, globally oldest
    store.services[0].load_into_memory(b)
    store.services[0].load_into_memory(c)  # overflow -> steals dirty a from ds1
    ds0, ds1 = store.services
    assert a not in ds1.cache and a not in ds1.dirty
    assert ds1.evictions == 1 and ds1.dirty_evictions == 1 and ds1.flushed_writes == 1
    assert ds0.evictions == 0
    assert store.metrics.dirty_evictions == 1 and store.metrics.flushed_writes == 1


def test_store_protected_evictions_surface_for_prefetch_aware():
    store = ObjectStore(n_services=1, cache_capacity=4, cache_policy="prefetch-aware")
    ds = store.services[0]
    pf = [store.put("X", {}) for _ in range(6)]
    for o in pf:
        store.prefetch_access(o)  # flood: 2 beyond-window bypass evictions
    assert store.protected_evictions() > 0
    store.reset_runtime_state()
    assert store.protected_evictions() == 0
    assert len(ds.cache) == 0 and len(ds.policy.tracked()) == 0


# ---------------------------------------------------------------------------
# property-based invariants over random op sequences
# ---------------------------------------------------------------------------

N_OBJECTS = 24
OP_KINDS = ("access", "write", "prefetch", "drop")


def _apply_ops(store, oids, ops):
    """Drive one op sequence; returns the number of dirty lines flushed by
    explicit ``drop_cache`` calls (the non-eviction flush path)."""
    explicit_flushes = 0
    for kind, idx in ops:
        if kind == "access":
            store.app_access(None, oids[idx % len(oids)])
        elif kind == "write":
            store.app_write(oids[idx % len(oids)])
        elif kind == "prefetch":
            store.prefetch_access(oids[idx % len(oids)])
        else:  # drop one service's cache
            ds = store.services[idx % len(store.services)]
            explicit_flushes += len(ds.dirty)
            ds.drop_cache()
    return explicit_flushes


def _check_invariants(store, capacity, shared, explicit_flushes):
    resident = {}
    for ds in store.services:
        # no oid is resident on a service while the policy thinks it is
        # evicted, and vice versa (residency and ordering metadata agree)
        if ds.budget is None:
            assert ds.policy.tracked() == set(ds.cache)
        # a dirty line is always resident (an evicted dirty line must have
        # been flushed and forgotten)
        assert ds.dirty <= set(ds.cache)
        assert not ds._inflight  # single-threaded: nothing left in flight
        for oid in ds.cache:
            assert oid not in resident  # no oid resident on two services
            resident[oid] = ds.ds_id
    if shared:
        assert sum(len(ds.cache) for ds in store.services) <= capacity
        assert set(store.budget.owner) == set(resident)
        assert store.budget.policy.tracked() == set(resident)
    elif capacity:
        for ds in store.services:
            assert len(ds.cache) <= capacity
    # every write-back was either a dirty eviction or an explicit flush
    assert store.metrics.flushed_writes == store.metrics.dirty_evictions + explicit_flushes
    per_ds_flushes = sum(ds.flushed_writes for ds in store.services)
    per_ds_dirty_ev = sum(ds.dirty_evictions for ds in store.services)
    assert per_ds_flushes == store.metrics.flushed_writes
    assert per_ds_dirty_ev == store.metrics.dirty_evictions


def _state_snapshot(store):
    return (
        store.metrics.snapshot(),
        [(ds.evictions, ds.dirty_evictions, ds.flushed_writes, sorted(ds.cache),
          sorted(ds.dirty)) for ds in store.services],
        sorted(store.accessed_oids),
        sorted(store.prefetched_oids),
        store.protected_evictions(),
    )


def _run_invariant_sequence(policy, capacity, shared, ops):
    store = ObjectStore(n_services=3, latency=ZERO, cache_capacity=capacity,
                        cache_policy=policy, shared_budget=shared)
    oids = [store.put("X", {"v": i}) for i in range(N_OBJECTS)]
    explicit = _apply_ops(store, oids, ops)
    _check_invariants(store, capacity, shared and bool(capacity), explicit)
    first = _state_snapshot(store)
    # replaying the same sequence after a reset reproduces the exact same
    # metrics: reset leaks no policy/budget/dirty state across repetitions
    store.reset_runtime_state()
    explicit = _apply_ops(store, oids, ops)
    _check_invariants(store, capacity, shared and bool(capacity), explicit)
    assert _state_snapshot(store) == first


@pytest.mark.parametrize("policy", TEST_POLICIES)
@pytest.mark.parametrize("shared", (False, True))
def test_invariants_on_seeded_random_sequences(policy, shared):
    """Deterministic pseudo-random sweep (runs even without hypothesis;
    ``test_eviction_properties.py`` deepens the same checker with
    hypothesis-generated sequences)."""
    for seed in range(8):
        rng = random.Random(seed)
        capacity = rng.choice((0, 1, 2, 3, 5, 8))
        ops = [
            (rng.choice(OP_KINDS), rng.randrange(N_OBJECTS))
            for _ in range(rng.randrange(10, 90))
        ]
        _run_invariant_sequence(policy, capacity, shared, ops)


# ---------------------------------------------------------------------------
# replay determinism and the thrash crossover
# ---------------------------------------------------------------------------


def _mask_train_seconds(path):
    """The wall-clock cells in an otherwise virtual-clock CSV (training time
    and the instrumentation's self-metered cost): blank them, return the
    rest of the file byte-for-byte."""
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    cols = [rows[0].index(c) for c in ("train_seconds", "obs_seconds")]
    for row in rows[1:]:
        for col in cols:
            row[col] = ""
    out = io.StringIO()
    csv.writer(out).writerows(rows)
    return out.getvalue()


def test_replay_csv_rows_are_byte_identical_across_runs(tmp_path):
    """Replaying the same recorded trace twice (same policy sweep) yields
    byte-identical CSV rows — guards the virtual clock against dict-order /
    threading nondeterminism.  ``train_seconds`` is the single wall-clock
    measurement in the file and is masked."""
    wl = _catalog()["bank"]
    recorded = record_workload(wl, runs=2)
    texts = []
    for i in range(2):
        results = evaluate_workload(
            wl, modes=("capre", "markov-miner"), cache_capacities=(0, 32),
            policies=("lru", "prefetch-aware"), recorded=recorded,
        )
        texts.append(_mask_train_seconds(write_csv(results, str(tmp_path / f"run{i}.csv"))))
    assert texts[0] == texts[1]


@pytest.mark.parametrize("app", ("bank", "oo7"))
def test_prefetch_aware_beats_lru_at_small_capacity(app):
    """The acceptance bar: at a small capacity the prefetch-aware policy
    loses strictly fewer prefetches before use and hides no less than LRU
    on the bank/oo7 traces; at unbounded capacity the policies agree."""
    wl = _catalog()[app]
    recorded = record_workload(wl, runs=2)
    small = {
        r.policy: r
        for r in evaluate_workload(wl, modes=("capre",), cache_capacities=(32,),
                                   policies=("lru", "prefetch-aware"), recorded=recorded)
    }
    lru, pa = small["lru"], small["prefetch-aware"]
    assert pa.overhead["evicted_before_use"] < lru.overhead["evicted_before_use"]
    assert pa.timely_coverage >= lru.timely_coverage
    assert pa.overhead["protected_evictions"] > 0
    assert lru.overhead["protected_evictions"] == 0
    unbounded = evaluate_workload(wl, modes=("capre",), cache_capacities=(0,),
                                  policies=("lru", "prefetch-aware"), recorded=recorded)
    a, b = unbounded
    assert (a.timely_coverage, a.stall_seconds, a.evictions) == (
        b.timely_coverage, b.stall_seconds, b.evictions
    )


def test_virtual_replay_shared_budget_matches_live_store_totals():
    """The same flood through both hosts of the shared budget: the replay
    engine and the live store evict the same count under one global
    capacity (one code path, one answer)."""
    n, cap = 2, 4
    live = ObjectStore(n_services=n, cache_capacity=cap, shared_budget=True)
    oids = [live.put("X", {}) for _ in range(10)]
    for o in oids:
        live.app_access(None, o)
    sim_store = ObjectStore(n_services=n)
    sim_oids = [sim_store.put("X", {}) for _ in range(10)]
    engine = VirtualReplay(sim_store, cache_capacity=cap, shared_budget=True)
    for o in sim_oids:
        engine.access(o)
    assert engine.evictions == sum(ds.evictions for ds in live.services) == 10 - cap
    assert sum(len(c) for c in engine.caches) == cap
