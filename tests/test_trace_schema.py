"""Trace schema v2 round-trips and back-compat (``pos.trace``).

The recorded event stream is the substrate of every offline comparison, so
its three accepted shapes — ``TraceEvent`` records, serialized tuples, and
v1 bare-oid lists — must all normalize identically through ``as_events`` /
``trace_oids``, including the ``write`` and ``method_entry`` kinds that the
replay engine otherwise only exercises indirectly."""

import json

import pytest

from repro.pos.store import ObjectStore
from repro.pos.trace import (
    ACCESS,
    DEMAND_KINDS,
    METHOD_ENTRY,
    WRITE,
    TraceEvent,
    access_event,
    as_events,
    method_entry_event,
    trace_oids,
    write_event,
)

MIXED = [
    method_entry_event("Bank.auditAll", 1),
    access_event(2),
    write_event(3),
    access_event(2),
    method_entry_event("Account.getCustomer", 4),
    write_event(5),
]


def test_event_constructors_and_kinds():
    assert access_event(7) == TraceEvent(ACCESS, 7)
    assert write_event(7) == TraceEvent(WRITE, 7)
    assert method_entry_event("C.m", 7) == TraceEvent(METHOD_ENTRY, 7, "C.m")
    assert access_event(7).is_demand and write_event(7).is_demand
    assert not method_entry_event("C.m", 7).is_demand
    assert set(DEMAND_KINDS) == {ACCESS, WRITE}


def test_to_tuple_round_trips_every_kind():
    wire = [ev.to_tuple() for ev in MIXED]
    assert wire[0] == (METHOD_ENTRY, "Bank.auditAll", 1)
    assert wire[1] == (ACCESS, 2)
    assert wire[2] == (WRITE, 3)
    assert as_events(wire) == MIXED


def test_to_tuple_survives_json():
    # the wire form is JSON-friendly (strings and ints only); JSON turns
    # tuples into lists, so a loader re-tuples before normalizing
    wire = json.loads(json.dumps([ev.to_tuple() for ev in MIXED]))
    assert as_events([tuple(item) for item in wire]) == MIXED


def test_as_events_accepts_legacy_enter_tuples():
    legacy = [("enter", "Bank.auditAll", 1), ("access", 2), ("write", 3)]
    events = as_events(legacy)
    assert events == [
        TraceEvent(METHOD_ENTRY, 1, "Bank.auditAll"),
        TraceEvent(ACCESS, 2),
        TraceEvent(WRITE, 3),
    ]


def test_as_events_accepts_v1_bare_oid_traces():
    # every v1 entry was an application-path read
    assert as_events([5, 6, 5]) == [
        TraceEvent(ACCESS, 5),
        TraceEvent(ACCESS, 6),
        TraceEvent(ACCESS, 5),
    ]


def test_as_events_passes_through_records_and_rejects_junk():
    assert as_events(MIXED) == MIXED
    with pytest.raises(TypeError):
        as_events([("frobnicate", 1)])
    with pytest.raises(TypeError):
        as_events([2.5])


def test_trace_oids_demand_kinds_and_filters():
    # method entries are scheduling points, not demand: excluded by default
    assert trace_oids(MIXED) == [2, 3, 2, 5]
    assert trace_oids(MIXED, kinds=(ACCESS,)) == [2, 2]
    assert trace_oids(MIXED, kinds=(WRITE,)) == [3, 5]
    assert trace_oids(MIXED, kinds=(METHOD_ENTRY,)) == [1, 4]
    # bare-oid lists pass through unchanged (pre-v2 recorded traces)
    assert trace_oids([9, 8, 9]) == [9, 8, 9]
    # mixed wire forms normalize before filtering
    assert trace_oids([ev.to_tuple() for ev in MIXED]) == [2, 3, 2, 5]


def test_recorded_store_trace_round_trips_through_wire_form():
    """End-to-end: a live store's schema-v2 trace serialized to tuples and
    normalized back is the identical event stream."""
    store = ObjectStore(n_services=2)
    a, b = store.put("X", {}), store.put("X", {})
    store.trace = []
    store.app_access(None, a)
    store.trace_method_entry("X.m", a)
    store.app_write(b)
    recorded = list(store.trace)
    assert [e.kind for e in recorded] == [ACCESS, METHOD_ENTRY, WRITE]
    assert as_events([e.to_tuple() for e in recorded]) == recorded
    assert trace_oids(recorded) == [a, b]
