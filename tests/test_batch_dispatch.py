"""Batched, placement-aware prefetch dispatch (ISSUE 5): per-oid vs batch
equivalence on the live store (every eviction policy, per-service and
shared-budget), predispatch dedupe accounting, the virtual-clock mirror
(``VirtualDisk.schedule_batch`` / ``VirtualReplay`` dispatch modes), the
drain-leak fix (warn + hard drain), trace memoization, latency calibration
arithmetic, and the WeightStreamer group-batch fan-out.

The policy matrix honors ``CAPRE_TEST_POLICIES`` like the eviction suite.
"""

import os
import threading
import warnings

import pytest

from repro.apps.bank import build_bank_app, populate_bank_store
from repro.pos.client import POSClient
from repro.pos.eviction import POLICIES
from repro.pos.executor import PrefetchRuntime
from repro.pos.latency import ZERO, LatencyModel, VirtualDisk
from repro.pos.store import ObjectStore
from repro.predict.evaluate import (
    RecordedTrace,
    VirtualReplay,
    _catalog,
    record_workload,
    replay,
)

TEST_POLICIES = tuple(
    p for p in os.environ.get("CAPRE_TEST_POLICIES", ",".join(POLICIES)).split(",") if p
)


# ---------------------------------------------------------------------------
# live-store equivalence: batch == per-oid where it must
# ---------------------------------------------------------------------------


def _run_live(dispatch, mode, capacity=0, policy="lru", shared=False, workload="auditAll"):
    client = POSClient(n_services=4, latency=ZERO, cache_capacity=capacity,
                       cache_policy=policy, shared_budget=shared)
    client.register(build_bank_app())
    root = populate_bank_store(client.store, n_transactions=40)
    warm_trace = None
    if mode in ("markov-miner", "hybrid"):
        client.store.trace = []
        with client.session("bank", mode=None) as s:
            s.execute(root, workload)
        warm_trace = list(client.store.trace)
        client.store.trace = None
        client.store.reset_runtime_state()
    with client.session("bank", mode=mode, dispatch=dispatch,
                        warm_trace=warm_trace) as s:
        s.execute(root, workload)
        assert s.drain(15.0)
    acc = client.store.prefetch_accuracy()
    return sorted(client.store.prefetched_oids), acc, client.store.snapshot_metrics()


@pytest.mark.parametrize("policy", TEST_POLICIES)
@pytest.mark.parametrize("shared", [False, True])
def test_batch_dispatch_identical_prefetched_set_per_policy(policy, shared):
    """At ZERO latency the batched dispatcher must prefetch byte-identical
    oid sets (and therefore identical accuracy) to the per-oid dispatcher,
    for every eviction policy, per-service and under a shared budget."""
    per_oid = _run_live("per-oid", "capre", capacity=32, policy=policy, shared=shared)
    batch = _run_live("batch", "capre", capacity=32, policy=policy, shared=shared)
    assert per_oid[0] == batch[0]
    assert per_oid[1] == batch[1]


# rop is excluded: its emissions are miss-driven, and which accesses miss
# depends on how fast earlier prefetches land — a feedback loop through the
# cache that is timing-dependent under EITHER dispatch mode.  The replay
# equivalence test below proves the dispatch layer itself is equivalent
# given identical emissions; the live test covers the predictors whose
# emission stream is deterministic.
@pytest.mark.parametrize("mode", ["capre", "markov-miner", "hybrid"])
def test_batch_dispatch_identical_accuracy_all_predictors(mode):
    per_oid = _run_live("per-oid", mode)
    batch = _run_live("batch", mode)
    assert per_oid[0] == batch[0], mode
    assert per_oid[1] == batch[1], mode
    # the batched dispatcher may REQUEST fewer oids (capre prunes
    # re-expansion of already-dispatched hint subtrees) but never more
    assert per_oid[2]["prefetch_requests"] >= batch[2]["prefetch_requests"], mode


def test_batch_dispatch_collapses_submission_count():
    _oids, _acc, metrics = _run_live("batch", "capre")
    per_oid_metrics = _run_live("per-oid", "capre")[2]
    # one injected method entry -> at most one batch task per Data Service
    # per streamed segment; the per-oid dispatcher paid one submission per
    # predicted oid (an order of magnitude more)
    n_seg = -(-per_oid_metrics["prefetch_requests"] // 64)  # StaticCapre.SEGMENT
    assert metrics["batch_dispatches"] <= 4 * n_seg
    assert per_oid_metrics["batch_dispatches"] == per_oid_metrics["prefetch_requests"]
    assert metrics["batch_dispatches"] * 5 < per_oid_metrics["batch_dispatches"]


def test_unknown_dispatch_mode_rejected():
    client = POSClient(n_services=1, latency=ZERO)
    client.register(build_bank_app())
    with pytest.raises(ValueError, match="unknown dispatch mode"):
        client.session("bank", mode="capre", dispatch="bogus")


# ---------------------------------------------------------------------------
# predispatch dedupe: cached and in-flight oids are suppressed but counted
# ---------------------------------------------------------------------------


def test_prefetch_batch_suppresses_cached_and_inflight():
    store = ObjectStore(n_services=1, latency=ZERO)
    ds = store.services[0]
    cached, inflight, fresh = (store.put("X", {}) for _ in range(3))
    ds.load_into_memory(cached)
    ev = threading.Event()
    ds._inflight[inflight] = ev  # a load someone else owns
    submitted = store.prefetch_batch([cached, inflight, fresh, fresh])
    ev.set()
    assert submitted == 1
    assert ds.dedup_suppressed == 3  # cached + in-flight + duplicate
    assert ds.prefetch_requests == 4
    assert ds.batch_dispatches == 1
    assert ds.prefetch_loads == 1  # only the fresh oid hit the disk
    assert ds.is_cached(fresh)
    # accuracy accounting still records every requested oid (what the
    # per-oid path reported): suppression is a dispatch optimization
    assert store.prefetched_oids == {cached, inflight, fresh}


def test_prefetch_batch_all_suppressed_submits_nothing():
    store = ObjectStore(n_services=1, latency=ZERO)
    ds = store.services[0]
    oids = [store.put("X", {}) for _ in range(3)]
    for o in oids:
        ds.load_into_memory(o)
    assert store.prefetch_batch(oids) == 0
    assert ds.batch_dispatches == 0
    assert ds.dedup_suppressed == 3
    assert ds.prefetch_loads == 0


def test_load_batch_skips_oids_that_landed_since_the_snapshot():
    store = ObjectStore(n_services=1, latency=ZERO)
    ds = store.services[0]
    a, b = store.put("X", {}), store.put("X", {})
    todo = ds.claim_prefetch_batch([a, b])
    assert todo == [a, b]
    ds.load_into_memory(a)  # a demand load wins the race
    ds.load_batch(todo)
    assert ds.is_cached(a) and ds.is_cached(b)
    assert ds.prefetch_loads == 1  # only b was loaded by the batch


# ---------------------------------------------------------------------------
# virtual-clock mirror
# ---------------------------------------------------------------------------

LAT = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=0.0, think=1.0,
                   parallel_per_ds=2)


def test_virtual_disk_schedule_batch_matches_serial_schedules():
    a, b = VirtualDisk(LAT), VirtualDisk(LAT)
    batch = a.schedule_batch(0.0, 4)
    serial = [b.schedule(0.0) for _ in range(4)]
    assert batch == serial
    assert a.loads == b.loads == 4


def _store_with(n_objects, n_services=2):
    store = ObjectStore(n_services=n_services)
    return store, [store.put("Obj", {}) for _ in range(n_objects)]


def test_replay_batch_dispatch_equivalent_at_zero_overhead():
    """With dispatch_overhead=0 the two replay modes produce identical
    timeliness (the slot arithmetic is the same); only the dispatch
    counters differ."""
    store, oids = _store_with(8)
    events = [("enter", "Obj.m", oids[0])] + [("access", o) for o in oids]
    trace = RecordedTrace("t", "m", events, list(oids))
    results = {}

    # a scripted predictor emitting everything at method entry
    from repro.predict.base import Predictor

    class Scripted(Predictor):
        name = "scripted"

        def on_method_entry(self, method_key, this_oid):
            return self._emit(list(oids))

    for dispatch in ("per-oid", "batch"):
        results[dispatch] = replay(trace, Scripted(), store, None,
                                   latency=LAT, dispatch=dispatch)
    per_oid, batch = results["per-oid"], results["batch"]
    assert per_oid.stall_seconds == batch.stall_seconds
    assert per_oid.timely_coverage == batch.timely_coverage
    assert per_oid.recall == batch.recall == 1.0
    # per-oid: one submission per emitted oid; batch: one per Data Service
    assert per_oid.batch_dispatches == len(oids)
    assert batch.batch_dispatches == 2  # two services hold the 8 oids
    assert batch.dedup_suppressed == 0
    assert per_oid.dispatch == "per-oid" and batch.dispatch == "batch"


def test_replay_per_oid_dispatch_overhead_delays_issue():
    """With a dispatch_overhead as large as a disk load, per-oid dispatch
    issues late loads so much later that timeliness collapses, while the
    batched dispatcher pays one overhead for the whole batch."""
    lat = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=0.0, think=1.0,
                      parallel_per_ds=2, dispatch_overhead=10.0)
    store, oids = _store_with(6, n_services=1)
    events = [("enter", "Obj.m", oids[0])] + [("access", o) for o in oids]
    trace = RecordedTrace("t", "m", events, list(oids))

    from repro.predict.base import Predictor

    class Scripted(Predictor):
        name = "scripted"

        def on_method_entry(self, method_key, this_oid):
            return self._emit(list(oids))

    per_oid = replay(trace, Scripted(), store, None, latency=lat, dispatch="per-oid")
    batch = replay(trace, Scripted(), store, None, latency=lat, dispatch="batch")
    assert batch.stall_seconds < per_oid.stall_seconds
    assert batch.timely_coverage >= per_oid.timely_coverage
    assert batch.batch_dispatches == 1


def test_replay_batch_counts_dedup_suppression():
    store, (a, b) = _store_with(2, n_services=1)
    engine = VirtualReplay(store, latency=LAT, dispatch="batch")
    engine.access(a)  # a is now resident (demand)
    engine.predict([a, b, b])  # a cached, b fresh, b duplicate
    assert engine.dedup_suppressed == 2
    assert engine.batch_dispatches == 1
    assert engine.prefetch_loads == 1


# ---------------------------------------------------------------------------
# static-optimizer signals through dispatch (ISSUE 8): RFO, truncation,
# priority ordering, admission control, modeled executor saturation
# ---------------------------------------------------------------------------


def _live_write_run(dispatch, rfo=True):
    client = POSClient(n_services=4, latency=ZERO)
    client.register(build_bank_app())
    root = populate_bank_store(client.store, n_transactions=40)
    with client.session("bank", mode="capre", dispatch=dispatch, rfo=rfo) as s:
        # fire the hint dispatch directly, with no demand accesses racing
        # the pool (executing the method at any latency makes who-loads-
        # first a scheduling race): every prefetch actually loads, so RFO
        # landings are exact
        s.predictor.on_method_entry("BankManagement.setAllTransCustomers", root)
        assert s.drain(15.0)
    return sorted(client.store.prefetched_oids), client.store.snapshot_metrics()


@pytest.mark.parametrize("dispatch", ["per-oid", "batch"])
def test_live_rfo_prefetches_dirty_allocate(dispatch):
    """Both live dispatch modes honor the hint RFO marks: prefetched update
    sites land dirty, and the counter flows into snapshot_metrics."""
    oids, metrics = _live_write_run(dispatch)
    assert metrics["prefetch_loads"] > 0
    assert metrics["rfo_prefetches"] > 0
    # RFO marks never change the emitted oid set itself: both modes still
    # request byte-identical prefetch sets.  Compared on the direct hint
    # dispatch above — running the full mutating workload live and
    # comparing two runs' sets is a scheduling race (the app's writes to
    # trans.cust race the expansion's field reads, so under CPU contention
    # the two runs legitimately expand different customers)
    other = "batch" if dispatch == "per-oid" else "per-oid"
    assert oids == _live_write_run(other)[0]


def test_live_rfo_disabled_by_session_config():
    _oids, metrics = _live_write_run("batch", rfo=False)
    assert metrics["prefetch_loads"] > 0
    assert metrics["rfo_prefetches"] == 0


def test_replay_rfo_equivalent_across_dispatch_modes():
    """Identical emissions + identical RFO marks -> identical stall and RFO
    accounting in both virtual dispatch modes."""
    store, oids = _store_with(8)
    events = ([("enter", "Obj.m", oids[0])] + [("access", o) for o in oids]
              + [("write", o) for o in oids])
    trace = RecordedTrace("t", "m", events, list(oids))

    from repro.predict.base import Predictor

    class Scripted(Predictor):
        name = "scripted"

        def on_method_entry(self, method_key, this_oid):
            return self._emit(list(oids), rfo=frozenset(oids),
                              priorities={o: 0.5 for o in oids})

    results = {d: replay(trace, Scripted(), store, None, latency=LAT, dispatch=d)
               for d in ("per-oid", "batch")}
    per_oid, batch = results["per-oid"], results["batch"]
    assert per_oid.stall_seconds == batch.stall_seconds
    r_per, r_batch = per_oid.row(), batch.row()
    assert r_per["rfo_prefetches"] == r_batch["rfo_prefetches"] == len(oids)
    # every write hit a dirty-allocated line: no ownership upgrades at all
    assert r_per["ownership_upgrades"] == r_batch["ownership_upgrades"] == 0
    assert r_per["hint_priority_mean"] == r_batch["hint_priority_mean"] == 0.5


def test_replay_rfo_off_pays_ownership_upgrades():
    """The A/B control: same trace, rfo disabled -> prefetches land clean
    and every write to a clean resident line pays the upgrade round trip."""
    lat = LatencyModel(disk_load=10.0, remote_hop=1.0, write_back=0.0,
                       think=0.1, parallel_per_ds=2)
    store, oids = _store_with(6, n_services=1)
    events = ([("enter", "Obj.m", oids[0])] + [("access", o) for o in oids]
              + [("write", o) for o in oids])
    trace = RecordedTrace("t", "m", events, list(oids))

    from repro.predict.base import Predictor

    def scripted():
        class Scripted(Predictor):
            name = "scripted"

            def on_method_entry(self, method_key, this_oid):
                return self._emit(list(oids), rfo=frozenset(oids))

        return Scripted()

    on = replay(trace, scripted(), store, None, latency=lat, rfo=True)
    off = replay(trace, scripted(), store, None, latency=lat, rfo=False)
    assert on.row()["rfo_prefetches"] == len(oids)
    assert off.row()["rfo_prefetches"] == 0
    assert off.row()["ownership_upgrades"] == len(oids)
    assert on.row()["ownership_upgrades"] == 0
    expect = len(oids) * lat.remote_hop
    assert off.stall_seconds - on.stall_seconds == pytest.approx(expect)


def test_bank_write_rfo_improves_calibrated_stall():
    """The acceptance criterion end to end: on the recorded mutating bank
    traversal, static-capre with RFO strictly beats RFO-off on stall."""
    wl = _catalog()["bank_write"]
    rows = {}
    for rfo in (True, False):
        client, _root, traces = record_workload(wl, runs=2)
        reg = client.logic_module.registered[wl.name]
        from repro.predict import make_pos_predictor

        predictor = make_pos_predictor("static-capre")
        predictor.warm(traces[0].accesses)
        rows[rfo] = replay(traces[-1], predictor, client.store, reg,
                           dispatch="batch", rfo=rfo)
    assert rows[True].row()["rfo_prefetches"] > 0
    assert rows[False].row()["ownership_upgrades"] > 0
    assert rows[True].stall_seconds < rows[False].stall_seconds


def test_iter_hint_tree_truncates_to_prefix_bound():
    """Partial-traversal truncation in the offline expander: the early-exit
    scan's hint expands only the static prefix of the collection."""
    from repro.core.opt import DEFAULT_PREFIX_BOUND
    from repro.predict.static_capre import StaticCapre

    wl = _catalog()["bank"]
    client, root, _traces = record_workload(wl, runs=1)
    reg = client.logic_module.registered["bank"]
    predictor = StaticCapre()
    predictor.attach(client.store, reg)
    out = predictor.on_method_entry("BankManagement.findLargeTransaction", root)
    # root + bounded prefix of transactions + their account.cust chains
    n_trans = sum(1 for o in out
                  if client.store.peek(o) and client.store.cls_of(o) == "Transaction")
    assert n_trans == DEFAULT_PREFIX_BOUND  # 40 transactions exist
    assert predictor.overhead.truncated_hints > 0
    # the full-traversal workload is NOT truncated
    p2 = StaticCapre()
    p2.attach(client.store, reg)
    out_full = p2.on_method_entry("BankManagement.auditAll", root)
    assert p2.overhead.truncated_hints == 0
    assert len(out_full) > len(out)


def test_replay_priority_orders_batches_and_admission_sheds():
    """PrefetchRuntime.admit: headroom admits everything; at the cap only
    priorities clearing the threshold get in."""
    rt = PrefetchRuntime(parallel_workers=1, max_outstanding=0)
    assert rt.admit(0.0)  # disabled: never sheds
    rt2 = PrefetchRuntime(parallel_workers=1, max_outstanding=1,
                          admission_threshold=0.5)
    release = threading.Event()
    rt2.fan_out(lambda _i: release.wait(10.0), [0])  # 1 outstanding = cap
    assert rt2.admit(0.9)       # above threshold: admitted even at cap
    assert not rt2.admit(0.1)   # below: shed
    assert rt2.admission_dropped == 1
    release.set()
    assert rt2.drain(5.0)
    rt.shutdown()
    rt2.shutdown()


def test_live_admission_control_sheds_low_priority_batches():
    store = ObjectStore(n_services=1, latency=ZERO)
    rt = PrefetchRuntime(parallel_workers=1, max_outstanding=1,
                         admission_threshold=0.5)
    release = threading.Event()
    rt.fan_out(lambda _i: release.wait(10.0), [0])
    oids = [store.put("X", {}) for _ in range(3)]
    n = store.prefetch_batch(oids, runtime=rt,
                             priorities={o: 0.1 for o in oids})
    assert n == 0  # the whole batch was shed
    assert rt.admission_dropped == 1
    n2 = store.prefetch_batch(oids, runtime=rt,
                              priorities={o: 0.9 for o in oids})
    assert n2 == 1
    release.set()
    assert rt.drain(5.0)
    rt.shutdown()


def test_virtual_executor_slots_saturate():
    """The modeled dispatch pool: with one slot, per-oid issues serialize
    behind each other's loads; with ample slots they overlap."""
    lat = LatencyModel(disk_load=10.0, remote_hop=0.0, write_back=0.0,
                       think=1.0, parallel_per_ds=8)
    store, oids = _store_with(4, n_services=1)
    narrow = VirtualReplay(store, latency=lat, executor_workers=1)
    narrow.predict(list(oids))
    assert narrow.exec_delayed == len(oids) - 1
    wide = VirtualReplay(store, latency=lat, executor_workers=8)
    wide.predict(list(oids))
    assert wide.exec_delayed == 0
    # serialized issue pushes each later load's completion out by a full
    # service time relative to the wide pool
    t_narrow = max(done for _s, done in narrow.inflight[0].values())
    t_wide = max(done for _s, done in wide.inflight[0].values())
    assert t_narrow > t_wide


# ---------------------------------------------------------------------------
# drain-leak regression (satellite): warn + hard drain
# ---------------------------------------------------------------------------


def test_hard_drain_cancels_queued_stragglers():
    rt = PrefetchRuntime(parallel_workers=1)
    release = threading.Event()
    ran = []
    rt.fan_out(lambda _i: release.wait(20.0), [0])  # occupies the only worker
    rt.fan_out(ran.append, range(5))  # queued behind it
    assert not rt.drain(0.2)
    assert not rt.hard_drain(0.2)  # cancels the queued 5; blocker still runs
    release.set()
    assert rt.drain(5.0)
    assert ran == []  # cancelled tasks never executed
    rt.shutdown()


def test_reset_runtime_state_warns_and_hard_drains_stragglers():
    store = ObjectStore(n_services=1, latency=ZERO)
    rt = PrefetchRuntime(parallel_workers=1)
    store.register_runtime(rt)
    release = threading.Event()
    oid = store.put("X", {})
    rt.fan_out(lambda _i: release.wait(20.0), [0])
    rt.fan_out(lambda _i: store.prefetch_access(oid), [0])  # would pollute
    with pytest.warns(RuntimeWarning, match="hard-draining"):
        store.reset_runtime_state(drain_timeout=0.2)
    release.set()
    assert rt.drain(5.0)
    # the straggler prefetch was cancelled: the fresh rep's state is clean
    assert store.prefetched_oids == set()
    assert store.snapshot_metrics()["prefetch_requests"] == 0
    rt.shutdown()
    store.unregister_runtime(rt)


def test_reset_runtime_state_quiet_when_idle():
    store = ObjectStore(n_services=1, latency=ZERO)
    rt = PrefetchRuntime(parallel_workers=1)
    store.register_runtime(rt)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        store.reset_runtime_state()
    rt.shutdown()


def test_session_close_unregisters_runtime():
    client = POSClient(n_services=1, latency=ZERO)
    client.register(build_bank_app())
    with client.session("bank", mode=None) as s:
        assert s.runtime in client.store._runtimes
    assert s.runtime not in client.store._runtimes


# ---------------------------------------------------------------------------
# trace memoization (satellite)
# ---------------------------------------------------------------------------


def test_trace_cache_round_trips_and_skips_reexecution(tmp_path):
    wl = _catalog()["bank_write"]  # mutating: store state must round-trip too
    cache = str(tmp_path / "traces")
    c1, root1, t1 = record_workload(wl, runs=2, cache_dir=cache)
    files = os.listdir(cache)
    assert len(files) == 1 and files[0].endswith(".json")
    c2, root2, t2 = record_workload(wl, runs=2, cache_dir=cache)
    assert root1 == root2
    assert [t.events for t in t1] == [t.events for t in t2]
    assert [t.accesses for t in t1] == [t.accesses for t in t2]
    # the cached store snapshot restores the post-recording (warm) state
    for ds1, ds2 in zip(c1.store.services, c2.store.services):
        assert {o: (r.cls, r.fields) for o, r in ds1.disk.items()} == \
               {o: (r.cls, r.fields) for o, r in ds2.disk.items()}


def test_trace_cache_invalidated_by_fingerprint_mismatch(tmp_path):
    import json

    wl = _catalog()["bank"]
    cache = str(tmp_path / "traces")
    _c, _root, t1 = record_workload(wl, runs=1, cache_dir=cache)
    path = os.path.join(cache, os.listdir(cache)[0])
    blob = json.load(open(path))
    blob["fingerprint"]["n_objects"] += 1  # simulate an app/populate change
    json.dump(blob, open(path, "w"))
    before = os.path.getmtime(path)
    _c, _root, t2 = record_workload(wl, runs=1, cache_dir=cache)
    assert [t.events for t in t1] == [t.events for t in t2]  # re-recorded
    assert os.path.getmtime(path) >= before  # entry was rewritten


# ---------------------------------------------------------------------------
# latency calibration (satellite): pure fit arithmetic
# ---------------------------------------------------------------------------


def test_calibration_fits_scale_and_residuals(tmp_path):
    from benchmarks.calibrate_latency import collect_pairs, write_report

    bench_rows = [
        {"benchmark": "predictors_bank", "config": "auditAll", "mode": "none",
         "mean_s": "1.0", "workload": "auditAll", "cache_capacity": "0",
         "policy": "lru", "dispatch": ""},
        {"benchmark": "predictors_bank", "config": "auditAll", "mode": "capre",
         "mean_s": "0.4", "workload": "auditAll", "cache_capacity": "0",
         "policy": "lru", "dispatch": "batch"},
    ]
    replay_rows = [
        {"app": "bank", "workload": "auditAll", "predictor": "static-capre",
         "cache_capacity": "0", "policy": "lru", "dispatch": "batch",
         "stall_seconds": "0.1", "baseline_stall_seconds": "0.4"},
    ]
    pairs = collect_pairs(bench_rows, replay_rows)
    assert len(pairs) == 1
    p = pairs[0]
    assert p.measured == pytest.approx(0.6)
    assert p.simulated == pytest.approx(0.3)
    out = write_report(pairs, str(tmp_path / "calibration.csv"))
    import csv as _csv

    rows = list(_csv.DictReader(open(out)))
    assert rows[0]["scale_app"] == "2.0000"
    assert float(rows[0]["residual_s"]) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# WeightStreamer group batching (satellite)
# ---------------------------------------------------------------------------


def test_weight_streamer_fetch_group_dedupes_and_fetches():
    pytest.importorskip("jax")
    import numpy as np

    from repro.runtime.prefetch import HostParamStore, WeightStreamer

    params = {f"layer{i}": {"w": np.ones((4, 4), np.float32)} for i in range(4)}
    store = HostParamStore(params, bandwidth_gbps=1000.0, base_latency_s=0.0)
    streamer = WeightStreamer(store, plan=None, mode=None, workers=2)
    paths = sorted(store.arrays)
    streamer.fetch_group(paths[:2])
    streamer.fetch_group(paths[:3])  # first two suppressed (cached/in-flight)
    for p in paths[:3]:
        streamer.get(p)
    assert streamer.metrics.fetches == 3
    assert streamer.metrics.dedup_suppressed == 2
    assert streamer.metrics.batch_dispatches >= 2
    streamer.close()
