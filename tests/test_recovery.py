"""Partition-tolerant recovery: network partitions + heal, readmission and
anti-entropy resync, write quorums, hedged reads, and fault detection under
churn — mirrored on the live threaded store and the deterministic replay
clock (ISSUE 10)."""

import threading

import pytest

from repro.pos.client import POSClient  # noqa: F401  (parity with suite imports)
from repro.pos.latency import ZERO, LatencyModel, make_scenario
from repro.pos.store import (
    ExecutionContext,
    ObjectStore,
    QuorumUnreachable,
    RetryExhausted,
    ServiceCrashed,
)
from repro.predict.evaluate import (
    _catalog,
    record_workload,
    replay_baseline,
)
from repro.predict.loadsim import run_loadsim


# ---------------------------------------------------------------------------
# live store: partitions, heal, readmission
# ---------------------------------------------------------------------------


def test_partition_reads_fail_over_and_heal_readmits():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 7})
    primary = store.replicas_of(oid)[0]
    store.partition([[], [primary]])  # primary lands on the minority side
    assert store.metrics.partitions == 1
    obj = store.app_access(ExecutionContext(store), oid)
    assert obj.fields["x"] == 7  # the reachable replica served it
    assert primary in store._down  # announced: routing avoids it outright
    assert store.metrics.failovers == 0  # no failed attempt was needed
    # a write during the cut cannot reach the cut replica: logged for resync
    store.app_write(oid)
    store.heal_partition()
    assert not store._net_cut
    assert primary not in store._down
    assert store.metrics.readmissions == 1
    assert store.metrics.resync_lines >= 1  # anti-entropy replayed the write
    assert store.metrics.lost_writes == 0


def test_partition_unannounced_is_caught_by_the_error_path():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 1})
    primary = store.replicas_of(oid)[0]
    store.partition([[], [primary]], announce=False)
    assert primary not in store._down  # undetected: routing still targets it
    obj = store.app_access(ExecutionContext(store), oid)
    assert obj.fields["x"] == 1
    assert primary in store._down  # ...until the failed load announced it
    assert store.metrics.failovers >= 1  # the error path paid the reroute


def test_revive_service_readmits_cold():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 3})
    victim = store.replicas_of(oid)[0]
    store.crash_service(victim)
    assert victim in store._down
    store.revive_service(victim)
    assert victim not in store._down
    assert store.services[victim].alive
    assert not store.services[victim].cache  # cold — the crash lost it
    assert store.metrics.readmissions == 1
    obj = store.app_access(ExecutionContext(store), oid)
    assert obj.fields["x"] == 3


def test_revive_resyncs_writes_missed_while_dead():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 0})
    victim = store.replicas_of(oid)[0]
    store.crash_service(victim)
    store.app_write(oid)  # served by the survivor; victim misses it
    flushed_before = store.metrics.flushed_writes
    store.revive_service(victim)
    assert store.metrics.resync_lines == 1
    assert store.metrics.flushed_writes == flushed_before + 1


def test_per_session_failover_attribution():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C", {"x": 1})
    store.services[store.replicas_of(oid)[0]].crash()  # silent
    ctx = ExecutionContext(store, session_label="tenant-a")
    store.app_access(ctx, oid)
    assert store.failovers_by_session.get("tenant-a", 0) >= 1


# ---------------------------------------------------------------------------
# live store: write-loss accounting and retry hygiene
# ---------------------------------------------------------------------------


def test_flush_on_dead_service_fails_over_to_replica():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C")
    primary = store.replicas_of(oid)[0]
    ds = store.services[primary]
    store.app_write(oid)
    ds.alive = False  # dies with the dirty line still queued for flush
    ds._flush(oid)
    assert store.metrics.lost_writes == 0
    assert store.metrics.flushed_writes >= 1  # the replica took the write-back


def test_flush_with_no_replica_counts_a_lost_write():
    store = ObjectStore(n_services=4, latency=ZERO, replication=1)
    oid = store.put("C")
    ds = store.services[store.replicas_of(oid)[0]]
    store.app_write(oid)
    ds.alive = False
    ds._flush(oid)
    assert store.metrics.lost_writes == 1


def test_demand_retries_are_bounded():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    oid = store.put("C")
    dead = store.services[store.replicas_of(oid)[0]]
    dead.crash()  # silent — and routing is pinned to the corpse below
    store._route_demand = lambda _oid: dead
    with pytest.raises(RetryExhausted) as exc:
        store.app_access(ExecutionContext(store), oid)
    assert exc.value.attempts == store.MAX_FAILOVER_RETRIES + 1
    assert store.metrics.failover_retries == store.MAX_FAILOVER_RETRIES


# ---------------------------------------------------------------------------
# live store: write quorums
# ---------------------------------------------------------------------------


def test_write_quorum_charges_synchronous_acks():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2,
                        write_quorum=2)
    oid = store.put("C")
    store.app_write(oid)
    assert store.metrics.quorum_writes == 1
    assert store.metrics.quorum_acks == 1  # W-1 acks for W=2
    assert store.metrics.quorum_failures == 0


def test_write_quorum_unreachable_across_partition():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2,
                        write_quorum=2)
    oid = store.put("C")
    other = store.replicas_of(oid)[1]
    store.partition([[], [other]])  # the ack-ing replica is across the cut
    with pytest.raises(QuorumUnreachable) as exc:
        store.app_write(oid)
    assert exc.value.wanted == 2 and exc.value.got == 1
    assert store.metrics.quorum_failures == 1
    assert store.metrics.quorum_retries == store.MAX_QUORUM_RETRIES
    # the local write stood (sloppy): the object is dirty on the primary
    primary = store.services[store.replicas_of(oid)[0]]
    assert oid in primary.dirty


def test_write_quorum_dirties_acking_replicas_resident_lines():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2,
                        write_quorum=2)
    oid = store.put("C")
    reps = store.replicas_of(oid)
    store.services[reps[1]].load_into_memory(oid)  # resident on the ack-er
    store.app_write(oid)
    assert oid in store.services[reps[1]].dirty


# ---------------------------------------------------------------------------
# live store: hedged reads
# ---------------------------------------------------------------------------


def test_hedged_read_wins_on_straggling_primary():
    latency = LatencyModel(disk_load=2e-3, remote_hop=0.0, write_back=0.0,
                           think=0.0).with_stragglers({0: 50.0})
    store = ObjectStore(n_services=4, latency=latency, replication=2,
                        hedge=True, hedge_delay=5e-3)
    oid = store.put("C", {"x": 9})  # round-robin: primary is service 0
    assert store.replicas_of(oid)[0] == 0
    obj = store.app_access(ExecutionContext(store), oid)
    assert obj.fields["x"] == 9
    assert store.metrics.hedged_reads == 1
    assert store.metrics.hedge_wins == 1  # 100ms primary lost to 2ms alt


def test_hedge_does_not_fire_on_fast_primary():
    store = ObjectStore(n_services=4, latency=ZERO, replication=2,
                        hedge=True, hedge_delay=1.0)
    oid = store.put("C")
    store.app_access(ExecutionContext(store), oid)
    assert store.metrics.hedged_reads == 0


# ---------------------------------------------------------------------------
# fault detection under churn
# ---------------------------------------------------------------------------


def test_detector_survives_crash_revive_churn():
    """Heartbeat/straggler ticks racing crash and revive threads: no
    exceptions, and a final readmission leaves every service routable."""
    store = ObjectStore(n_services=4, latency=ZERO, replication=2)
    det = store.attach_fault_detection(heartbeat_timeout=1e6, check_every=1)
    oids = [store.put("C", {"v": i}) for i in range(16)]
    stop = threading.Event()
    errors = []

    def churn():
        try:
            for _ in range(100):
                store.crash_service(0)
                store.revive_service(0)
        except Exception as exc:  # pragma: no cover - the assertion payload
            errors.append(exc)
        finally:
            stop.set()

    th = threading.Thread(target=churn)
    th.start()
    reader_errors = 0
    while not stop.is_set():
        for ds_id in range(4):
            det.beat(ds_id, 1e-4)
        det.tick(force=True)
        for oid in oids[:4]:
            try:
                store.app_access(ExecutionContext(store), oid)
            except (ServiceCrashed, RetryExhausted):
                reader_errors += 1  # bounded failure beats a hang
    th.join(timeout=10.0)
    assert not th.is_alive() and not errors
    store.revive_service(0)
    assert not store._down
    for oid in oids:
        assert store.app_access(ExecutionContext(store), oid) is not None


def test_readmission_clears_straggler_flag_and_history():
    store = ObjectStore(n_services=4, latency=ZERO)
    det = store.attach_fault_detection(straggler_threshold=2.0,
                                      straggler_min_samples=4,
                                      straggler_patience=1, check_every=1)
    for _ in range(3):
        det.beat(0, 1.0)
        for ds_id in (1, 2, 3):
            det.beat(ds_id, 0.01)
    det.tick(force=True)
    assert 0 in store._slow
    store.revive_service(0)
    assert 0 not in store._slow
    assert store.metrics.readmissions == 1
    # a clean baseline: the old strikes must not re-flag it instantly
    det.tick(force=True)
    assert 0 not in store._slow


# ---------------------------------------------------------------------------
# virtual clock: the same recovery semantics, deterministically
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bank_recorded():
    wl = _catalog()["bank"]
    return wl, record_workload(wl, runs=2)


@pytest.fixture(scope="module")
def bank_write_recorded():
    wl = _catalog()["bank_write"]
    return wl, record_workload(wl, runs=2)


def _end_t(trace, store):
    clean = replay_baseline(trace, store)
    return clean.t - clean.stall_seconds


def test_virtual_partition_fails_over_and_heals(bank_recorded):
    _, (client, _root, traces) = bank_recorded
    store = client.store
    store.rebuild_placement("round-robin", replication=2)
    trace = traces[-1]
    sc = make_scenario("partition", end_t=_end_t(trace, store))
    engine = replay_baseline(trace, store, scenario=sc)
    assert engine.failovers > 0
    assert engine.readmissions >= 1  # the heal fired at heal_at
    assert not engine.cut  # nothing left unreachable at the end
    assert not engine.dead


def test_virtual_crash_revive_readmits(bank_recorded):
    _, (client, _root, traces) = bank_recorded
    store = client.store
    store.rebuild_placement("round-robin", replication=2)
    trace = traces[-1]
    sc = make_scenario("crash+revive", end_t=_end_t(trace, store))
    engine = replay_baseline(trace, store, scenario=sc)
    assert engine.readmissions == 1
    assert not engine.dead  # revived before the run ended


def test_virtual_quorum_prices_replicated_writes(bank_write_recorded):
    _, (client, _root, traces) = bank_write_recorded
    store = client.store
    store.rebuild_placement("round-robin", replication=2)
    trace = traces[-1]
    sloppy = replay_baseline(trace, store, write_quorum=1)
    quorum = replay_baseline(trace, store, write_quorum=2)
    assert quorum.quorum_writes > 0
    assert quorum.quorum_acks == quorum.quorum_writes  # W-1 acks each, W=2
    assert quorum.stall_seconds > sloppy.stall_seconds  # consistency costs
    assert quorum.quorum_failures == 0  # both replicas healthy throughout


def test_virtual_hedge_cuts_straggler_stall(bank_recorded):
    _, (client, _root, traces) = bank_recorded
    store = client.store
    store.rebuild_placement("round-robin", replication=2)
    trace = traces[-1]
    plain = replay_baseline(trace, store,
                            scenario=make_scenario("straggler"))
    hedged = replay_baseline(trace, store,
                             scenario=make_scenario("straggler+hedge"))
    assert hedged.hedged_reads > 0
    assert hedged.hedge_wins > 0
    assert hedged.stall_seconds <= plain.stall_seconds


def test_virtual_replay_is_deterministic_under_faults(bank_recorded):
    _, (client, _root, traces) = bank_recorded
    store = client.store
    store.rebuild_placement("round-robin", replication=2)
    trace = traces[-1]
    end_t = _end_t(trace, store)
    for name in ("partition", "crash+revive", "straggler+hedge"):
        sc = make_scenario(name, end_t=end_t)
        a = replay_baseline(trace, store, scenario=sc, write_quorum=2)
        b = replay_baseline(trace, store, scenario=sc, write_quorum=2)
        assert (a.t, a.stall_seconds, a.failovers, a.readmissions,
                a.hedged_reads) == \
               (b.t, b.stall_seconds, b.failovers, b.readmissions,
                b.hedged_reads), name


# ---------------------------------------------------------------------------
# multi-tenant virtual loadsim under faults
# ---------------------------------------------------------------------------


def test_loadsim_partition_scenario_is_deterministic():
    kwargs = dict(tenants=8, jobs=1, scenario="partition", replication=2,
                  cache_capacity=64)
    a = run_loadsim(**kwargs)
    b = run_loadsim(**kwargs)
    assert a.rows() == b.rows()
    assert a.scenario == "partition"
    assert a.failovers >= 1  # the cut's detection charge at minimum


def test_loadsim_rows_carry_scenario_and_failover_columns():
    report = run_loadsim(tenants=4, jobs=1, scenario="crash", replication=2,
                         cache_capacity=64)
    rows = report.rows()
    assert rows and all("scenario" in r and "failovers" in r for r in rows)
    assert all(r["scenario"] == "crash" for r in rows)
