"""Multi-tenant harness + the three concurrency bugs it exposed (PR 9).

Covers DESIGN.md §3.10: per-call span attribution (two labeled sessions
must not clobber each other's tracer identity), session lifecycle hygiene
(50 open/close cycles leave the obs registry and store listeners at
baseline, with process-unique labels), the WeightStreamer timeout fallback
(an expired in-flight wait serves a synchronous fetch instead of raising
KeyError), and the virtual-clock load simulator (deterministic rows,
admission shedding, interference attribution).
"""

from __future__ import annotations

import threading
import time

import pytest

from repro.obs import Observability
from repro.pos.client import POSClient, Session, SessionConfig
from repro.predict.evaluate import _catalog
from repro.predict.loadsim import (
    LOADGEN_COLUMNS,
    heavy_tailed_weights,
    parse_arrival,
    run_loadsim,
)


def _bank_client(tracing: bool = True):
    wl = _catalog()["bank"]
    client = POSClient(n_services=2)
    obs = Observability(tracing=tracing)
    client.store.attach_obs(obs)
    client.register(wl.build_app())
    root = wl.populate(client.store)
    return client, obs, wl, root


# ---------------------------------------------------------------------------
# bugfix 1: span attribution is per-call, not shared tracer state
# ---------------------------------------------------------------------------


def test_two_labeled_sessions_attribute_spans_to_their_own_label():
    """Creating session B after session A must not relabel A's spans.

    The old code did ``store.obs.tracer.session = label`` in
    ``Session.__init__`` — whichever session was constructed *last* owned
    every span on the shared store, so two concurrent tenants were
    indistinguishable in the timeline."""
    client, obs, wl, root = _bank_client()
    reg = client.logic_module.registered[wl.name]
    sa = Session(client.store, reg,
                 SessionConfig(mode="capre", session_label="tA"))
    sb = Session(client.store, reg,
                 SessionConfig(mode="capre", session_label="tB"))
    try:
        # B was constructed last; under the clobbered-tracer behavior A's
        # spans now carry "tB"
        wl.run_once(sa, root)
        sa.drain(10.0)
        labels = {s.session for s in obs.tracer.spans() if s.session}
        assert "tA" in labels
        assert "tB" not in labels  # B never ran anything
        wl.run_once(sb, root)
        sb.drain(10.0)
        labels = {s.session for s in obs.tracer.spans() if s.session}
        assert {"tA", "tB"} <= labels
    finally:
        sa.close()
        sb.close()


def test_concurrent_labeled_sessions_interleave_attribution():
    client, obs, wl, root = _bank_client()
    reg = client.logic_module.registered[wl.name]

    def drive(label: str) -> None:
        s = Session(client.store, reg,
                    SessionConfig(mode="capre", session_label=label))
        try:
            wl.run_once(s, root)
            s.drain(10.0)
        finally:
            s.close()

    threads = [threading.Thread(target=drive, args=(lbl,))
               for lbl in ("tX", "tY")]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    labels = {s.session for s in obs.tracer.spans() if s.session}
    assert {"tX", "tY"} <= labels


def test_demand_stalls_land_in_the_tenant_histogram():
    client, obs, wl, root = _bank_client(tracing=False)
    reg = client.logic_module.registered[wl.name]
    with Session(client.store, reg,
                 SessionConfig(session_label="tH")) as s:
        wl.run_once(s, root)
    hist = obs.registry.histogram("tenant_stall_s", tenant="tH")
    assert hist.count > 0  # every demand event recorded a (possibly 0) stall


# ---------------------------------------------------------------------------
# bugfix 2: session lifecycle leaves no registry/listener residue
# ---------------------------------------------------------------------------


def test_open_close_churn_restores_registry_and_listeners():
    client, obs, wl, root = _bank_client(tracing=False)
    reg = client.logic_module.registered[wl.name]
    baseline_sources = set(obs.registry.source_names())
    baseline_listeners = (client.store.miss_listener,
                          client.store.access_listener)
    labels = []
    for _ in range(50):
        s = Session(client.store, reg, SessionConfig(mode="capre"))
        labels.append(s.label)
        s.close()
    # the old register_source had no inverse: 50 runtime/<label> sources
    # (minus the id()-collision overwrites) accumulated forever
    assert set(obs.registry.source_names()) == baseline_sources
    assert not any(n.startswith("runtime/")
                   for n in obs.registry.source_names())
    assert (client.store.miss_listener,
            client.store.access_listener) == baseline_listeners
    # the old default label, id(self) & 0xFFFF, collides under churn
    # (CPython reuses freed addresses); the counter scheme never does
    assert len(set(labels)) == 50


def test_unregister_source_reports_membership():
    from repro.obs import Registry

    r = Registry()
    r.register_source("x", lambda: {})
    assert r.unregister_source("x") is True
    assert r.unregister_source("x") is False
    assert "x" not in r.source_names()


# ---------------------------------------------------------------------------
# bugfix 3: WeightStreamer timeout fallback (was a bare KeyError)
# ---------------------------------------------------------------------------


class _StallingStore:
    """First fetch blocks until released (a stuck pool lane); later
    fetches (the demand-path fallback) return immediately."""

    def __init__(self):
        import numpy as np

        self.arr = np.ones((8,), np.float32)
        self.release = threading.Event()
        self._lock = threading.Lock()
        self.calls = 0

    def fetch(self, path):
        with self._lock:
            self.calls += 1
            first = self.calls == 1
        if first:
            self.release.wait(10.0)
        return self.arr

    def nbytes(self, path):
        return self.arr.nbytes


def test_streamer_timeout_serves_fallback_and_counts_it():
    from repro.runtime.prefetch import WeightStreamer

    store = _StallingStore()
    ws = WeightStreamer(store, plan=None, mode=None, workers=1,
                        fetch_timeout=0.05)
    try:
        ws.fetch_group(["w"])  # lane 0 wedges on the first fetch
        t0 = time.perf_counter()
        arr = ws.get("w")  # old behavior: KeyError after the 30s wait
        assert arr.shape == (8,)
        assert ws.metrics.fetch_timeouts == 1
        assert ws.metrics.stalls == 1
        assert store.calls == 2  # async lane + sync fallback
        assert time.perf_counter() - t0 < 5.0
        # once the wedged lane lands, later gets are plain cache hits
        store.release.set()
        assert ws.get("w").shape == (8,)
        assert ws.metrics.fetch_timeouts == 1
    finally:
        store.release.set()
        ws.close()


def test_streamer_workers_zero_still_constructs_a_pool():
    from repro.runtime.prefetch import WeightStreamer

    store = _StallingStore()
    store.release.set()  # nothing should block in this test
    # the old ctor passed the raw ``workers`` to ThreadPoolExecutor while
    # clamping only its bookkeeping copy: workers=0 raised ValueError
    ws = WeightStreamer(store, plan=None, mode=None, workers=0)
    try:
        assert ws.get("w").shape == (8,)
    finally:
        ws.close()


# ---------------------------------------------------------------------------
# the virtual-clock load simulator
# ---------------------------------------------------------------------------


def test_loadsim_rows_are_deterministic_across_runs():
    kw = dict(tenants=6, arrival="poisson:400", jobs=2, seed=11,
              mix=("bank", "wordcount"), cache_capacity=64,
              shared_budget=True, max_outstanding=4,
              admission_threshold=0.5)
    a = run_loadsim(**kw)
    b = run_loadsim(**kw)
    assert a.rows() == b.rows()


def test_loadsim_row_schema_and_aggregate():
    rep = run_loadsim(tenants=4, arrival="closed", jobs=1, seed=3,
                      mix=("bank", "wordcount"), cache_capacity=64,
                      shared_budget=True)
    rows = rep.rows()
    assert len(rows) == 5  # 4 tenants + ALL
    for row in rows:
        assert set(row) == set(LOADGEN_COLUMNS)
        assert row["clock"] == "virtual"
        assert row["wall_s"] == ""  # byte-reproducible: no wall cells
    agg = rows[-1]
    assert agg["tenant"] == "ALL"
    assert agg["ops"] == sum(r["ops"] for r in rows[:-1])
    assert agg["fairness_ratio"] == round(rep.fairness_ratio, 4)
    per_tenant_ops = [r["ops"] for r in rows[:-1]]
    assert all(ops > 0 for ops in per_tenant_ops)


def test_loadsim_admission_mirror_sheds_under_pressure():
    kw = dict(tenants=8, arrival="poisson:5000", jobs=2, seed=5,
              mix=("bank", "kmeans"), cache_capacity=64,
              shared_budget=True)
    open_gate = run_loadsim(**kw, max_outstanding=0)
    throttled = run_loadsim(**kw, max_outstanding=2,
                            admission_threshold=2.0)  # nothing bypasses
    assert sum(t.admission_shed for t in open_gate.per_tenant) == 0
    assert sum(t.admission_shed for t in throttled.per_tenant) > 0


def test_loadsim_attributes_interference_to_tenants():
    rep = run_loadsim(tenants=8, arrival="closed", jobs=1, seed=7,
                      mix=("bank", "wordcount"), cache_capacity=32,
                      shared_budget=True)
    # a 32-line shared budget under 8 tenants must destroy someone's
    # unused prefetches, and the owner map must name the victims
    assert sum(t.evicted_before_use for t in rep.per_tenant) > 0
    assert sum(t.evicted_before_use for t in rep.per_tenant) <= rep.evictions


def test_parse_arrival_and_mix_weights():
    assert parse_arrival("closed") == ("closed", 0.0)
    assert parse_arrival("poisson:250") == ("poisson", 250.0)
    with pytest.raises(ValueError):
        parse_arrival("poisson:0")
    with pytest.raises(ValueError):
        parse_arrival("uniform:10")
    w = heavy_tailed_weights(4)
    assert w == sorted(w, reverse=True) and w[0] == 1.0
