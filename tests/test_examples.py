"""Example-rot guard: every example in examples/ must run end to end
(reduced sizes via CLI args where available)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(script: str, *args: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, str(ROOT / "examples" / script), *args],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert out.returncode == 0, f"{script} failed:\n{out.stderr[-3000:]}"
    return out.stdout


def test_quickstart_runs_and_shows_hints():
    out = _run("quickstart.py")
    assert "PH[BankManagement.setAllTransCustomers]" in out
    assert "transactions[].account.cust.company" in out
    assert "capre" in out


def test_train_lm_reduces_loss_and_resumes():
    out = _run("train_lm.py", "--steps", "30", "--batch", "4", "--seq", "64")
    assert "loss:" in out and "resume check: restored step" in out


def test_serve_lm_generates_and_streams():
    out = _run("serve_lm.py")
    assert "access plan" in out
    assert "prefetch_hits" in out
