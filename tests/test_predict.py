"""The pluggable prediction subsystem: registry resolution, the four
predictors online (live Session) and offline (trace replay), trace
recording order, accuracy edge cases, and the store/streamer accounting
fixes that ride along."""

import threading
import time

import pytest

from repro.apps.bank import build_bank_app, populate_bank_store
from repro.pos.client import POSClient, Session, SessionConfig
from repro.pos.latency import ZERO, LatencyModel
from repro.pos.store import ObjectStore, prefetch_accuracy
from repro import predict
from repro.predict.evaluate import (
    _catalog,
    evaluate_workload,
    record_workload,
    replay,
)


@pytest.fixture()
def client():
    c = POSClient(n_services=4, latency=ZERO)
    c.register(build_bank_app())
    return c


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_exposes_all_four_predictors():
    names = predict.available(kind="pos")
    assert {"static-capre", "rop", "markov-miner", "hybrid"} <= set(names)
    assert set(predict.available(kind="stream")) >= {"static-capre", "rop"}


def test_registry_aliases_keep_historical_mode_strings():
    assert predict.canonical("capre") == "static-capre"
    assert predict.canonical("markov") == "markov-miner"
    assert isinstance(predict.make_pos_predictor("capre"), predict.StaticCapre)


def test_registry_unknown_mode_raises_with_candidates(client):
    with pytest.raises(KeyError, match="static-capre"):
        predict.get("palantir")
    with pytest.raises(KeyError, match="unknown prefetch mode"):
        client.session("bank", mode="nope")


def test_all_registered_modes_run_live(client):
    root = populate_bank_store(client.store, n_transactions=20)
    # warm trace for the miners
    client.store.trace = []
    with client.session("bank", mode=None) as s:
        s.execute(root, "auditAll")
    warm = list(client.store.trace)
    client.store.trace = None
    for mode in predict.available(kind="pos"):
        client.store.reset_runtime_state()
        with client.session("bank", mode=mode, warm_trace=warm) as s:
            s.execute(root, "auditAll")
            assert s.drain(10.0)
        assert client.store.snapshot_metrics()["prefetch_requests"] > 0, mode


# ---------------------------------------------------------------------------
# trace recording
# ---------------------------------------------------------------------------


def test_trace_records_accesses_in_navigation_order(client):
    from repro.pos.trace import trace_oids

    root = populate_bank_store(client.store, n_transactions=10)
    client.store.trace = []
    with client.session("bank", mode=None) as s:
        s.execute(root, "auditAll")
    events = client.store.trace
    # schema v2: typed events — the read-only traversal records accesses
    # and method entries, no writes
    assert {e.kind for e in events} == {"access", "method_entry"}
    trace = trace_oids(events)
    assert trace[0] == root  # the receiver is accessed first
    assert len(trace) == client.store.metrics.app_loads
    assert set(trace) == client.store.accessed_oids
    # auditAll navigates each transaction before its type/emp/account chain
    tx_oids = client.store.peek(root).fields["transactions"]
    first_tx = trace.index(tx_oids[0])
    chain = client.store.peek(tx_oids[0]).fields
    assert trace.index(chain["type"]) > first_tx
    assert trace.index(chain["emp"]) > first_tx
    # the method entry for auditAll is recorded right after the root access
    assert events[1].kind == "method_entry" and events[1].oid == root
    assert events[1].method_key.endswith("auditAll")


def test_trace_reset_and_off_by_default(client):
    root = populate_bank_store(client.store, n_transactions=5)
    assert client.store.trace is None
    with client.session("bank", mode=None) as s:
        s.execute(root, "auditAll")
    assert client.store.trace is None  # never turned on implicitly
    client.store.trace = []
    client.store.reset_runtime_state()
    assert client.store.trace == []  # reset keeps recording enabled


# ---------------------------------------------------------------------------
# accuracy accounting edge cases
# ---------------------------------------------------------------------------


def test_prefetch_accuracy_empty_sets():
    """Nothing prefetched, nothing accessed: both ratios are *undefined*,
    not 0.0 — phantom zeros used to be indistinguishable from a measured
    total miss."""
    acc = prefetch_accuracy(set(), set())
    assert acc["true_positives"] == 0
    assert acc["precision"] is None and acc["recall"] is None
    assert acc["evaluated"] is False


def test_prefetch_accuracy_all_false_positives():
    acc = prefetch_accuracy({1, 2, 3}, set())
    assert acc["false_positives"] == 3
    assert acc["precision"] == 0.0  # defined: 3 emissions, all useless
    assert acc["recall"] is None  # undefined: nothing was ever accessed
    assert acc["evaluated"] is True


def test_prefetch_accuracy_all_false_negatives():
    acc = prefetch_accuracy(set(), {7, 8})
    assert acc["false_negatives"] == 2
    assert acc["recall"] == 0.0  # defined: 2 accesses, none prefetched
    assert acc["precision"] is None  # undefined: the predictor emitted nothing
    assert acc["evaluated"] is False


def test_prefetch_accuracy_mixed_matches_store_method(client):
    client.store.prefetched_oids = {1, 2, 3}
    client.store.accessed_oids = {2, 3, 4}
    acc = client.store.prefetch_accuracy()
    assert acc == prefetch_accuracy({1, 2, 3}, {2, 3, 4})
    assert acc["precision"] == pytest.approx(2 / 3)
    assert acc["recall"] == pytest.approx(2 / 3)


# ---------------------------------------------------------------------------
# offline replay harness
# ---------------------------------------------------------------------------


def test_recorded_trace_roundtrips_through_replay():
    wl = _catalog()["bank"]
    client, root, traces = record_workload(wl, runs=2)
    train, eval_ = traces
    # deterministic read-only traversal: both runs record identical streams
    assert train.events == eval_.events
    assert train.accesses[0] == root
    assert [e.oid for e in eval_.events if e.kind in ("access", "write")] == eval_.accesses
    reg = client.logic_module.registered["bank"]
    # static replay of the recorded trace reaches the live session's recall
    res = replay(eval_, predict.make_pos_predictor("capre"), client.store, reg)
    assert res.recall >= 0.99
    assert res.true_positives + res.false_negatives == len(set(eval_.accesses))


def test_offline_replay_matches_live_accuracy_for_capre(client):
    """The replay harness and the live store agree on CAPre's accuracy for
    the same deterministic workload."""
    wl = _catalog()["bank"]
    client2, root, traces = record_workload(wl, runs=1)
    reg = client2.logic_module.registered["bank"]
    offline = replay(traces[0], predict.make_pos_predictor("capre"), client2.store, reg)
    client2.store.reset_runtime_state()
    with Session(client2.store, reg, SessionConfig(mode="capre")) as s:
        s.execute(root, "auditAll")
        assert s.drain(10.0)
    live = client2.store.prefetch_accuracy()
    assert offline.recall == pytest.approx(live["recall"], abs=0.02)


def test_markov_beats_rop_recall_on_collection_workload():
    """K-Means has no single associations: ROP predicts nothing while the
    trace miner reconstructs the access sequence (the acceptance bar)."""
    results = {r.predictor: r for r in evaluate_workload(
        _catalog()["kmeans"], modes=("rop", "markov-miner"), rop_depth=5
    )}
    assert results["rop"].recall == 0.0
    assert results["markov-miner"].recall > 0.9
    assert results["markov-miner"].recall > results["rop"].recall
    # and the miner paid for it: table memory + monitored events
    assert results["markov-miner"].overhead["table_bytes"] > 0
    assert results["markov-miner"].overhead["monitor_events"] > 0
    assert results["rop"].overhead["table_bytes"] == 0


def test_static_capre_charges_zero_monitoring():
    results = {r.predictor: r for r in evaluate_workload(
        _catalog()["bank"], modes=("capre", "markov-miner")
    )}
    assert results["static-capre"].overhead["monitor_events"] == 0
    assert results["markov-miner"].overhead["monitor_events"] > 0
    assert results["static-capre"].recall >= 0.99


def test_evaluate_apps_covers_three_benchmarks():
    from repro.predict.evaluate import evaluate_apps, format_table

    results = evaluate_apps(apps=("bank", "wordcount", "kmeans"),
                            modes=("capre", "rop", "markov-miner", "hybrid"))
    assert len(results) == 12
    table = format_table(results)
    assert "wordcount" in table and "hybrid" in table and "recall" in table


# ---------------------------------------------------------------------------
# live markov session (online monitoring path)
# ---------------------------------------------------------------------------


def test_live_markov_session_prefetches_after_warm(client):
    root = populate_bank_store(client.store, n_transactions=30)
    client.store.trace = []
    with client.session("bank", mode=None) as s:
        s.execute(root, "auditAll")
    warm = list(client.store.trace)
    client.store.trace = None
    client.store.reset_runtime_state()
    with client.session("bank", mode="markov-miner", warm_trace=warm) as s:
        s.execute(root, "auditAll")
        assert s.drain(10.0)
        overhead = s.predictor.overhead
    acc = client.store.prefetch_accuracy()
    assert acc["recall"] > 0.9
    assert overhead.monitor_events == client.store.metrics.app_loads
    assert overhead.table_bytes > 0
    # listeners are removed on close
    assert client.store.access_listener is None


def test_live_hybrid_covers_collections_and_singles(client):
    root = populate_bank_store(client.store, n_transactions=30)
    client.store.trace = []
    with client.session("bank", mode=None) as s:
        s.execute(root, "auditAll")
    warm = list(client.store.trace)
    client.store.trace = None
    client.store.reset_runtime_state()
    with client.session("bank", mode="hybrid", warm_trace=warm) as s:
        s.execute(root, "auditAll")
        assert s.drain(10.0)
    acc = client.store.prefetch_accuracy()
    assert acc["recall"] > 0.95


# ---------------------------------------------------------------------------
# DataService coalescing fixes (satellite)
# ---------------------------------------------------------------------------


def test_waiter_recovers_when_owner_never_lands_the_load():
    """A pre-set in-flight event whose owner never cached the object must
    not satisfy a waiter: it re-takes ownership and performs the load."""
    store = ObjectStore(n_services=1, latency=ZERO)
    ds = store.services[0]
    a = store.put("X", {})
    ev = threading.Event()
    ev.set()
    ds._inflight[a] = ev  # owner died after signalling, before landing
    assert ds.load_into_memory(a) is True
    assert ds.is_cached(a)
    assert a not in ds._inflight


def test_coalesced_waiter_gets_lru_bump():
    """The waiter's access counts for LRU recency: after waking it must
    bump the object it waited for, not leave it at the owner's position."""
    store = ObjectStore(n_services=1, latency=ZERO, cache_capacity=3)
    ds = store.services[0]
    a, b, c, d = [store.put("X", {}) for _ in range(4)]
    ev = threading.Event()
    ds._inflight[a] = ev
    result = []
    waiter = threading.Thread(target=lambda: result.append(ds.load_into_memory(a)))
    waiter.start()
    time.sleep(0.05)  # waiter is parked on the in-flight event
    with ds._cache_lock:
        ds._touch(a)  # the "owner's" load lands: a is oldest…
    ds.load_into_memory(b)
    ds.load_into_memory(c)  # …after b and c load: LRU order a, b, c
    ev.set()
    waiter.join(timeout=5.0)
    assert result == [False]  # coalesced, no second disk load
    ds.load_into_memory(d)  # one eviction: the waiter's bump saves a
    assert ds.is_cached(a)
    assert not ds.is_cached(b)


def test_coalescing_still_single_loads_under_concurrency():
    lat = LatencyModel(disk_load=20e-3, remote_hop=0.0, write_back=0.0, think=0.0)
    store = ObjectStore(n_services=1, latency=lat)
    ds = store.services[0]
    a = store.put("X", {})
    results = []
    threads = [
        threading.Thread(target=lambda: results.append(ds.load_into_memory(a)))
        for _ in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=5.0)
    assert sorted(results) == [False, False, False, True]
    assert ds.is_cached(a)


# ---------------------------------------------------------------------------
# WeightStreamer wasted-bytes accounting (satellite)
# ---------------------------------------------------------------------------


def _tiny_streamer(mode=None, **kw):
    import numpy as np

    from repro.core.access_plan import AccessRecord, PrefetchPlan
    from repro.runtime.prefetch import HostParamStore, WeightStreamer

    params = {"g0": np.zeros(64, np.float32), "g1": np.ones(64, np.float32)}
    plan = PrefetchPlan(records=[
        AccessRecord(path="g0", first_use=0, nbytes=256, shape=(64,)),
        AccessRecord(path="g1", first_use=1, nbytes=256, shape=(64,)),
    ])
    store = HostParamStore(params, bandwidth_gbps=100.0, base_latency_s=0.0)
    return WeightStreamer(store, plan=plan, mode=mode, **kw)


def test_wasted_bytes_charged_at_eviction_time():
    ws = _tiny_streamer(mode=None)
    ws._fetch_async("g0")  # prefetched…
    deadline = time.time() + 5.0
    while "g0" not in ws._cache and time.time() < deadline:
        time.sleep(0.001)
    ws._evict_before(1)  # …then evicted without ever being served
    assert ws.metrics.wasted_bytes == 256
    ws.close()


def test_used_arrays_not_counted_as_waste():
    ws = _tiny_streamer(mode="capre")
    ws.run_plan()
    assert ws.metrics.wasted_bytes == 0
    assert ws.metrics.stalls <= 2
    ws.close()


def test_streamer_resolves_modes_through_registry():
    import pytest as _pytest

    with _pytest.raises(KeyError, match="unknown prefetch mode"):
        _tiny_streamer(mode="nope")
    ws = _tiny_streamer(mode="markov-miner", warm_group_trace=[-1, 0, 1])

    def drain_inflight(_gi, _arrays):
        # A prefetch only counts as a hit if the pool thread lands it
        # before the compute thread's next get() — a pure scheduling race
        # on a loaded box.  Waiting out the in-flight fetches here (the
        # policy registers them synchronously in on_group_start, and
        # run_plan calls compute_fn before the next group's gets) makes
        # the mined g0->g1 prefetch a deterministic cache hit.
        while True:
            with ws._lock:
                evs = list(ws._inflight.values())
            if not evs:
                return
            for ev in evs:
                ev.wait(5.0)

    ws.run_plan(compute_fn=drain_inflight)
    assert ws.metrics.prefetch_hits >= 1  # mined -1->0->1 transitions fired
    assert ws.group_log == [-1, 0, 1]
    ws.close()
