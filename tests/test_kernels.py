"""Per-kernel validation: interpret-mode Pallas vs the pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5), jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Sq,Sk,H,KV,D", [
    (1, 128, 128, 4, 4, 64),
    (2, 128, 256, 4, 2, 64),
    (1, 256, 256, 8, 1, 128),
    (2, 64, 64, 2, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, Sq, Sk, H, KV, D, dtype, causal):
    if causal and Sq != Sk:
        pytest.skip("causal requires aligned q/k positions in this sweep")
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(B, Sq, H, D), dtype)
    k = jnp.asarray(rng.randn(B, Sk, KV, D), dtype)
    v = jnp.asarray(rng.randn(B, Sk, KV, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_q_offset_decode_chunk():
    """Chunked prefill: queries at offset within the kv sequence."""
    rng = np.random.RandomState(1)
    B, Sq, Sk, H, D = 1, 64, 256, 2, 64
    q = jnp.asarray(rng.randn(B, Sq, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Sk, H, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Sk, H, D), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, q_offset=192, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=192)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,KV,D,kv_len", [
    (1, 512, 4, 4, 64, 512),
    (2, 512, 8, 2, 64, 300),
    (1, 1024, 4, 1, 128, 7),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, S, H, KV, D, kv_len, dtype):
    rng = np.random.RandomState(2)
    q = jnp.asarray(rng.randn(B, H, D), dtype)
    k = jnp.asarray(rng.randn(B, S, KV, D), dtype)
    v = jnp.asarray(rng.randn(B, S, KV, D), dtype)
    got = ops.decode_attention(q, k, v, kv_len, block_k=128)
    want = ref.decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# prefetch gather
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,D,B", [(64, 128, 8), (1000, 384, 17), (16, 130, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefetch_gather_matches_ref(N, D, B, dtype):
    rng = np.random.RandomState(3)
    table = jnp.asarray(rng.randn(N, D), dtype)
    idx = jnp.asarray(rng.randint(0, N, size=B), jnp.int32)
    got = ops.prefetch_gather(table, idx)
    want = ref.prefetch_gather_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 64),
    b=st.integers(1, 16),
    data=st.data(),
)
def test_prefetch_gather_property(n, b, data):
    """Hint-driven gather == direct indexing, for any hint set."""
    idx = data.draw(st.lists(st.integers(0, n - 1), min_size=b, max_size=b))
    table = jnp.arange(n * 128, dtype=jnp.float32).reshape(n, 128)
    got = ops.prefetch_gather(table, jnp.asarray(idx, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(table)[idx])


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,W", [(1, 128, 256), (2, 64, 128), (3, 256, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_matches_ref(B, S, W, dtype):
    rng = np.random.RandomState(4)
    a = jnp.asarray(rng.uniform(0.5, 0.99, size=(B, S, W)), dtype)
    g = jnp.asarray(0.1 * rng.randn(B, S, W), dtype)
    got = ops.rglru_scan(a, g, block_s=32, block_m=128)
    # oracle over the folded layout
    want = jax.vmap(lambda aa, gg: ref.rglru_scan_ref(aa, gg))(a, g)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@settings(max_examples=20, deadline=None)
@given(s=st.integers(2, 32), seed=st.integers(0, 2**16))
def test_rglru_zero_decay_returns_input(s, seed):
    """Property: a == 0 -> h_t == g_t exactly."""
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(1, s, 128), jnp.float32)
    a = jnp.zeros_like(g)
    y = ops.rglru_scan(a, g, block_s=max(1, s // 2), block_m=128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(g), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,C,N", [(1, 64, 256, 16), (2, 32, 128, 8), (1, 128, 512, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_matches_ref(B, S, C, N, dtype):
    rng = np.random.RandomState(5)
    dA = jnp.asarray(rng.uniform(0.3, 0.99, size=(B, S, C, N)), dtype)
    dBu = jnp.asarray(0.1 * rng.randn(B, S, C, N), dtype)
    Cm = jnp.asarray(rng.randn(B, S, N), dtype)
    got = ops.mamba_scan(dA, dBu, Cm, block_s=16, block_c=64)
    want = jax.vmap(ref.mamba_scan_ref)(dA, dBu, Cm)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_mamba_single_step_property(seed):
    """Property: with S == 1, y = (dBu . C) (h0 = 0)."""
    rng = np.random.RandomState(seed)
    dA = jnp.asarray(rng.rand(1, 1, 128, 8), jnp.float32)
    dBu = jnp.asarray(rng.randn(1, 1, 128, 8), jnp.float32)
    Cm = jnp.asarray(rng.randn(1, 1, 8), jnp.float32)
    y = ops.mamba_scan(dA, dBu, Cm, block_s=1, block_c=128)
    want = np.einsum("cn,n->c", np.asarray(dBu[0, 0]), np.asarray(Cm[0, 0]))
    np.testing.assert_allclose(np.asarray(y[0, 0]), want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model-layer consistency: the chunked jnp attention (what the dry-run
# lowers) agrees with the Pallas kernel and the naive reference
# ---------------------------------------------------------------------------


def test_model_chunked_attention_agrees_with_kernel():
    from repro.models.layers import gqa_attention

    rng = np.random.RandomState(6)
    B, S, H, KV, D = 2, 128, 4, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, S, KV, D), jnp.float32)
    a_model = gqa_attention(q, k, v, causal=True, impl="chunked", chunk=32)
    a_kernel = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(a_model), np.asarray(a_kernel), rtol=2e-4, atol=2e-4)
