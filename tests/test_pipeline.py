"""Pipeline-parallelism feature test (subprocess with 4 fake devices):
the GPipe schedule over 4 stages reproduces the sequential stack exactly."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_gpipe_matches_sequential():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.launch.pipeline import gpipe

        S, L_per, M, mb, d = 4, 2, 8, 2, 16
        mesh = Mesh(np.asarray(jax.devices()[:S]), ("stage",))
        rng = np.random.RandomState(0)
        # stage params: [S, L_per, d, d]
        ws = jnp.asarray(rng.randn(S, L_per, d, d) * 0.3, jnp.float32)
        xs = jnp.asarray(rng.randn(M, mb, d), jnp.float32)

        def stage_fn(sp, x):
            for i in range(L_per):
                x = jnp.tanh(x @ sp[i])
            return x

        run = gpipe(stage_fn, mesh)
        got = jax.jit(run)(ws, xs)

        # sequential reference
        ref = xs
        out = []
        for m in range(M):
            x = xs[m]
            for s in range(S):
                x = stage_fn(ws[s], x)
            out.append(x)
        ref = jnp.stack(out)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("GPIPE-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True, text=True, env=env, timeout=600)
    assert "GPIPE-OK" in out.stdout, out.stderr[-3000:]
